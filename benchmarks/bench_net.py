"""Cross-host serving tier benchmark: the two-process localhost cluster.

Spawns real ``python -m repro.net.host`` processes — each with its OWN
copy of the operator store wrapped in the spindle-emulating throttle (one
lock + proportional sleep per store path), so every host owns one
emulated SSD spindle — and drives them through a
:class:`~repro.net.frontdoor.ClusterFrontDoor` over the wire protocol.

Two claims, mirroring the fleet section of ``bench_runtime`` one level up:

* **Scale-out across hosts.**  One host serializes a backlog of mixed
  tenants (multiply / power-iteration / PageRank / BFS, all riding the
  same column-stochastic operator) on its single spindle; two hosts with
  disjoint spindles clear the same backlog roughly twice as fast, because
  the front door's least-estimated-backlog routing keeps both streaming.
  The CI gate (``check_regression.py --runtime``) holds the 2-host/1-host
  speedup trajectory and an absolute >= 1.5x floor.
* **Host-level failover.**  Killing one host process mid-serve (SIGKILL,
  no goodbye) must not lose a tenant: the front door evicts the host on
  heartbeat/connection loss and resubmits its in-flight specs to the
  survivor, and — sessions being deterministic replays — every result is
  still bit-identical to a lone in-process ``ServingFleet``.  Asserted
  here and gated in CI.

* **Partitioned scale-out for one wide query.**  A single iterative query
  cannot be split by the tenant router — it is one tenant.  Submitted
  with ``partitioned=True``, each of its passes instead spans every live
  host, each scanning only its nnz-balanced tile-row slab of its own
  spindle, and the front door stitches the row blocks; 2 hosts must beat
  1 by >= 1.4x (gated in CI), and killing a slab host mid-query must
  reassign only the lost slab to the survivor, still bit-identically.

``REPRO_BENCH_QUICK=1`` shrinks the graph, iteration counts, and spindle
throttle to a seconds-long run.  All ten host processes (five for the
tenant-routing phases, five for the partitioned phases — each phase
shuts its hosts down when it finishes) are spawned up front so their
interpreter/jax import costs overlap instead of serializing across
phases.
"""
from __future__ import annotations

import os
import shutil
import subprocess
import sys
import tempfile
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from benchmarks.common import print_csv, save
from repro.apps.pagerank import build_operator, dangling_vertices
from repro.core.formats import to_chunked
from repro.io.storage import TileStore
from repro.net import ClusterFrontDoor
from repro.runtime import ReplicaSet, ServingFleet, SessionSpec
from repro.sparse.generate import rmat

QUICK = os.environ.get("REPRO_BENCH_QUICK") == "1"

# (rmat scale, power/pagerank iterations, spindle seconds per full pass,
#  per-wave column capacity, one-shot multiply tenants)
SCALE = 11 if QUICK else 13
ITERS = 8 if QUICK else 12
PASS_SECONDS = 0.1 if QUICK else 0.25
# The partitioned phases measure spindle ownership of ONE query's scan:
# a heavier throttle keeps the per-pass RPC/stitch overhead small against
# the slab scan time, and a finer tile grid (T=512 vs the tenant phases'
# 1024) gives the nnz-balanced tile-row split enough granularity to
# actually halve a skewed rmat store.
PART_PASS_SECONDS = 0.3 if QUICK else 0.75
PART_T = 512
CAPACITY = 4
N_MULTIPLY = 2 if QUICK else 4

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _mixed_specs(adj, n: int) -> Tuple[List[SessionSpec], int]:
    """The mixed tenant backlog (every kind rides the one PageRank-operator
    store) and its total column-pass cost — the unit of served work."""
    rng = np.random.default_rng(41)
    specs: List[SessionSpec] = []
    col_passes = 0
    for i in range(N_MULTIPLY):
        x = rng.standard_normal(n).astype(np.float32)
        specs.append(SessionSpec.multiply(x, tenant_id=f"mul-{i}"))
        col_passes += 1
    for i in range(ITERS // 2):
        x0 = rng.standard_normal(n).astype(np.float32)
        specs.append(SessionSpec.power_iteration(
            x0, tol=0.0, max_iter=ITERS, tenant_id=f"pow-{i}"))
        col_passes += ITERS
    specs.append(SessionSpec.pagerank(
        n, dangling_vertices(adj).astype(np.uint8), tol=0.0, max_iter=ITERS,
        tenant_id="pr-0"))
    col_passes += ITERS
    specs.append(SessionSpec.bfs(
        np.array([0], dtype=np.int64), n, tenant_id="bfs-0"))
    col_passes += 1  # lower bound; BFS retires on frontier convergence
    return specs, col_passes


def _reference_results(path: str, specs: Sequence[SessionSpec]
                       ) -> Dict[str, np.ndarray]:
    """The lone in-process ServingFleet every cluster phase must match
    bit-for-bit (unthrottled — correctness, not timing)."""
    fleet = ServingFleet(ReplicaSet([TileStore.open(path)]), n_waves=1,
                         capacity=CAPACITY)
    try:
        sessions = [s.build() for s in specs]
        for s in sessions:
            fleet.submit(s)
        fleet.drain(300)
        return {s.tenant_id: np.asarray(s.result) for s in sessions}
    finally:
        fleet.close()


def _spawn_host(store_path: str,
                pass_seconds: float = PASS_SECONDS) -> subprocess.Popen:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in [os.path.join(REPO_ROOT, "src"),
                    env.get("PYTHONPATH", "")] if p)
    return subprocess.Popen(
        [sys.executable, "-m", "repro.net.host", "--store", store_path,
         "--waves", "1", "--capacity", str(CAPACITY), "--no-cache",
         "--throttle-pass-seconds", str(pass_seconds)],
        stdout=subprocess.PIPE, stderr=subprocess.DEVNULL, env=env,
        text=True)


def _scrape_port(proc: subprocess.Popen, deadline_s: float = 120.0) -> int:
    t0 = time.time()
    while True:
        line = proc.stdout.readline()
        if line.startswith("LISTENING "):
            return int(line.split()[1])
        if proc.poll() is not None or time.time() - t0 > deadline_s:
            raise RuntimeError("host process died before LISTENING "
                               f"(rc={proc.returncode})")


def _warmup(ports: Sequence[int], n: int) -> None:
    """One throwaway multiply per host so every process pays its jit
    compile before the timed phases (all hosts in parallel)."""
    from concurrent.futures import ThreadPoolExecutor

    def one(port: int) -> None:
        door = ClusterFrontDoor(heartbeat_interval=0.2)
        try:
            door.add_host("127.0.0.1", port)
            door.submit(SessionSpec.multiply(
                np.ones(n, np.float32), tenant_id="warmup")).wait(300)
        finally:
            door.close()

    with ThreadPoolExecutor(len(ports)) as ex:
        list(ex.map(one, ports))


def _serve(ports: Sequence[int], specs: Sequence[SessionSpec],
           reference: Dict[str, np.ndarray],
           kill: Optional[subprocess.Popen] = None) -> dict:
    """Serve the backlog through a front door over ``ports``; returns wall
    seconds, host spread, and failover counters.  ``kill`` SIGKILLs that
    host process mid-serve (the failover phase)."""
    door = ClusterFrontDoor(heartbeat_interval=0.1, miss_limit=3,
                            deliver_poll_s=0.5)
    try:
        for p in ports:
            door.add_host("127.0.0.1", p)
        t0 = time.perf_counter()
        tickets = [door.submit(s) for s in specs]
        if kill is not None:
            time.sleep(2.5 * PASS_SECONDS)  # mid-pass, work still in flight
            kill.kill()
        door.drain(tickets, timeout=600)
        seconds = time.perf_counter() - t0
        for t in tickets:
            np.testing.assert_array_equal(t.result, reference[t.tenant_id])
        return {
            "seconds": seconds,
            "hosts_used": len({t.host_key for t in tickets}),
            "completed": sum(t.done for t in tickets),
            "resubmits": sum(t.resubmits for t in tickets),
            "evicted": len(door.evicted),
        }
    finally:
        door.shutdown_hosts()
        door.close()


def _serve_partitioned(ports: Sequence[int], n: int, spec: SessionSpec,
                       reference: Dict[str, np.ndarray],
                       kill: Optional[subprocess.Popen] = None) -> dict:
    """One wide query partitioned across ``ports``: every pass broadcasts
    the iterate and each host scans only its tile-row slab.  ``kill``
    SIGKILLs that host mid-query — only its slab should move."""
    door = ClusterFrontDoor(heartbeat_interval=0.1, miss_limit=3,
                            deliver_poll_s=0.5)
    try:
        for p in ports:
            door.add_host("127.0.0.1", p)
        # throwaway partitioned multiply: builds every host's lazy slab
        # executors and pays the slab-shaped jit compiles before timing
        door.submit(SessionSpec.multiply(np.ones(n, np.float32),
                                         tenant_id="pwarm"),
                    partitioned=True).wait(300)
        t0 = time.perf_counter()
        ticket = door.submit(spec, partitioned=True)
        if kill is not None:
            time.sleep(1.5 * PART_PASS_SECONDS)  # mid-query, slabs in flight
            kill.kill()
        result = ticket.wait(600)
        seconds = time.perf_counter() - t0
        np.testing.assert_array_equal(result, reference[spec.tenant_id])
        return {
            "seconds": seconds,
            "slabs": ticket.plan.n_slabs,
            "resubmits": ticket.resubmits,
            "reassignments": ticket.plan.reassignments,
            "evicted": len(door.evicted),
        }
    finally:
        door.shutdown_hosts()
        door.close()


def main() -> List[dict]:
    adj = rmat(SCALE, 8, seed=5)
    op = build_operator(adj)
    ct = to_chunked(op, T=1024, C=128)
    tmp = tempfile.mkdtemp(prefix="bench_net_")
    procs: List[subprocess.Popen] = []
    try:
        # one store copy per host process = one emulated spindle each,
        # plus an unthrottled copy for the in-process reference fleet
        paths = [os.path.join(tmp, f"store{i}") for i in range(6)]
        TileStore.write(paths[0], ct)
        for p in paths[1:]:
            shutil.copy(paths[0] + ".bin", p + ".bin")
            shutil.copy(paths[0] + ".json", p + ".json")
        # the partitioned phases get their own copies: same matrix, finer
        # tile grid (PART_T), heavier per-spindle throttle.  Bit-identity
        # is judged against a same-grid unthrottled reference — tile size
        # changes row grouping, so cross-grid bits are not comparable.
        ct_p = to_chunked(op, T=PART_T, C=128)
        ppaths = [os.path.join(tmp, f"pstore{i}") for i in range(6)]
        TileStore.write(ppaths[0], ct_p)
        for p in ppaths[1:]:
            shutil.copy(ppaths[0] + ".bin", p + ".bin")
            shutil.copy(ppaths[0] + ".json", p + ".json")

        # spawn all ten hosts up front: interpreter+jax imports overlap
        procs = [_spawn_host(p) for p in paths[1:]] + \
                [_spawn_host(p, PART_PASS_SECONDS) for p in ppaths[1:]]
        ports = [_scrape_port(pr) for pr in procs]

        n = op.shape[1]
        specs, col_passes = _mixed_specs(adj, n)
        rng = np.random.default_rng(43)
        pspec = SessionSpec.power_iteration(
            rng.standard_normal(n).astype(np.float32), tol=0.0,
            max_iter=ITERS, tenant_id="part-0")
        reference = _reference_results(paths[0], specs)
        preference = _reference_results(ppaths[0], [pspec])
        _warmup(ports[:5], n)

        one = _serve(ports[:1], specs, reference)
        two = _serve(ports[1:3], specs, reference)
        speedup = one["seconds"] / two["seconds"]
        fo = _serve(ports[3:5], specs, reference, kill=procs[3])
        print(f"  1 host: {one}\n  2 hosts: {two}\n  failover: {fo}")

        part1 = _serve_partitioned(ports[5:6], n, pspec, preference)
        part2 = _serve_partitioned(ports[6:8], n, pspec, preference)
        pspeedup = part1["seconds"] / part2["seconds"]
        pfo = _serve_partitioned(ports[8:10], n, pspec, preference,
                                 kill=procs[8])
        print(f"  partitioned 1 host: {part1}\n"
              f"  partitioned 2 hosts: {part2}\n"
              f"  partitioned failover: {pfo}")

        assert two["hosts_used"] == 2, \
            "front door left a registered host idle"
        assert speedup > 1.0, \
            f"2-host cluster slower than one host ({speedup:.2f}x)"
        assert fo["evicted"] == 1 and fo["resubmits"] >= 1, \
            f"kill-host phase saw no failover ({fo})"
        assert fo["completed"] == len(specs), \
            f"failover lost tenants ({fo['completed']}/{len(specs)})"
        assert part2["slabs"] == 2, \
            "partitioned query did not span both hosts"
        assert pspeedup > 1.0, \
            f"partitioned 2-host query slower than 1 host ({pspeedup:.2f}x)"
        assert pfo["evicted"] == 1 and pfo["resubmits"] >= 1 \
            and pfo["reassignments"] >= 1, \
            f"kill-slab-host phase saw no slab failover ({pfo})"

        rows = [
            {"workload": "cluster_throughput", "mode": "hosts-1",
             "hosts": 1, "tenants": len(specs), "seconds": one["seconds"],
             "col_passes_per_s": col_passes / one["seconds"]},
            {"workload": "cluster_throughput", "mode": "hosts-2",
             "hosts": 2, "tenants": len(specs), "seconds": two["seconds"],
             "col_passes_per_s": col_passes / two["seconds"]},
            {"workload": "cluster_failover", "mode": "hosts-2-kill-1",
             "hosts": 2, "tenants": len(specs), "seconds": fo["seconds"],
             "completed": fo["completed"], "resubmits": fo["resubmits"],
             "evicted": fo["evicted"], "bit_identical": 1},
            {"workload": "cluster_partitioned", "mode": "slabs-1",
             "hosts": 1, "passes": ITERS, "seconds": part1["seconds"]},
            {"workload": "cluster_partitioned", "mode": "slabs-2",
             "hosts": 2, "passes": ITERS, "seconds": part2["seconds"]},
            {"workload": "cluster_partitioned_failover",
             "mode": "slabs-2-kill-1", "hosts": 2, "passes": ITERS,
             "seconds": pfo["seconds"], "resubmits": pfo["resubmits"],
             "reassignments": pfo["reassignments"],
             "evicted": pfo["evicted"], "bit_identical": 1},
        ]
        print_csv("net_cluster_throughput", rows[:2])
        print_csv("net_cluster_failover", rows[2:3])
        print_csv("net_cluster_partitioned", rows[3:])
        print(f"  2-host speedup vs 1 host: {speedup:.2f}x "
              f"(failover resubmits: {fo['resubmits']}); partitioned "
              f"2-host speedup: {pspeedup:.2f}x "
              f"(slab reassignments: {pfo['reassignments']})")
        save("net_cluster", rows)
        return rows
    finally:
        for pr in procs:
            if pr.poll() is None:
                pr.terminate()
        for pr in procs:
            try:
                pr.wait(timeout=10)
            except subprocess.TimeoutExpired:
                pr.kill()
        shutil.rmtree(tmp, ignore_errors=True)


if __name__ == "__main__":
    main()
