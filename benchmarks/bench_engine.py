"""Streaming-engine ablation: serial vs overlapped vs sharded scans, on raw
and on reordered + delta-compressed (optimized) stores.

The paper's headline mechanism is that SEM-SpMM hides SSD latency behind
compute; this bench measures how much of that hiding the pipelined engine
actually delivers, on a >= 1M-nnz R-MAT graph with p = 8.  The graph is
streamed as a *binary adjacency* store (the paper's canonical workload —
values synthesized on device) and the operand is small-integer, so every
engine x store combination is bit-identical: integer arithmetic makes even
the reordered store's different accumulation grouping exact.

Container protocol (DESIGN.md §7 / benchmarks.common): the file lands in
the page cache, so raw reads are far faster *relative to this machine's
compute* than the paper's SSD-vs-48-cores balance.  To validate the
engine's structure rather than the page cache, the ablation also runs
against an *emulated SSD* whose streaming time is calibrated to the
measured compute time of one pass — the paper's regime, where stream time
~= compute time at small p (that balance is exactly why overlap matters).
The no-throttle wall-times are reported alongside, unasserted.

Asserted claims:
* overlapped engine >= 1.3x the serial path on the emulated SSD (>= 1.2 in
  quick mode, where the pass is only a handful of batches);
* host->device *index* bytes cut by exactly 8 bytes/lane by the device-side
  decode (binary store: the host path ships int32 rows + int32 cols +
  synthesized float32 ones = 12 B/lane, the device path raw uint16 planes
  = 4 B/lane);
* ``TileStore.optimize`` (degree reordering + uint8 delta packing) cuts
  both bytes streamed per pass and h2d bytes per pass by >= 25% on every
  engine that ships packed planes (the serial ablation decodes on the
  host, so its h2d traffic is the decoded 12 B/lane either way);
* every engine on every tier — raw or optimized store, 4-way sharded,
  Pallas wave kernel (gather variant, interpret mode on this container) —
  is bit-identical to the single-scan pass on the raw store.

``REPRO_BENCH_QUICK=1`` (set by ``benchmarks.run --quick``) shrinks the
graph and batch sizes to a seconds-long run — the CI regression gate's
mode.  Quick numbers are only comparable to quick numbers; the gate keeps
full and quick trajectories separate (see ``benchmarks/check_regression``).
"""
from __future__ import annotations

import os
import tempfile
import time
from typing import Dict, List

import numpy as np

from repro.core.formats import to_chunked
from repro.core.sem import SEMConfig, SEMSpMM
from repro.distributed.shard_scan import ShardedSEMSpMM
from repro.io.storage import TileStore
from repro.sparse.generate import rmat

from benchmarks.common import quick_mode, run_and_save, timeit

QUICK = quick_mode()
P = 8
if QUICK:   # tiny emulated-SSD sizes: seconds, not minutes
    SCALE, NNZ_MIN, C, T, BATCH, MIN_SPEEDUP = 14, 200_000, 512, 2048, 64, 1.2
else:
    SCALE, NNZ_MIN, C, T, BATCH, MIN_SPEEDUP = 17, 1_000_000, 1024, 4096, \
        192, 1.3
# BATCH does not divide the chunk count -> exercises the padded tail
MIN_SHRINK = 0.25   # optimize() must cut streamed and h2d bytes by >= 25%

SERIAL = dict(decode_on_device=False, overlap=False, fixed_shape=False,
              use_async=False)
# The Pallas wave-kernel backend, pinned to the gather variant (what
# pick_variant chooses at the paper's 16K tiles, and the variant that is
# bit-identical to the _batch_step engine) so full and quick modes measure
# the same code path; interpret mode per the CPU-container protocol.
PALLAS = dict(use_pallas=True, pallas_variant="gather")
ENGINES = (("serial", SERIAL, 0),
           ("overlapped", {}, 0),
           ("pallas", PALLAS, 0),
           ("sharded-4", {}, 4))


class EmulatedSSDStore(TileStore):
    """TileStore throttled to a fixed pass time: sleeps in the read path
    (i.e. inside the prefetch thread when streaming async), emulating an
    SSD whose bandwidth : compute balance matches the paper's machine.
    The sleep is proportional to the *actual on-disk bytes* of the range
    (``range_nbytes``), not ``record * count`` — an optimized store's
    packed chunks are smaller than the header's worst-case record, and
    that saving is exactly what the opt rows measure."""

    seconds_per_byte = 0.0

    def read_batch_raw(self, start, count):
        time.sleep(self.seconds_per_byte * self.range_nbytes(start, count))
        return super().read_batch_raw(start, count)

    def partition_rows(self, n_shards):
        # Shards inherit the class (TileStore.partition_rows uses
        # type(self)) but the throttle is per-instance state — copy it so
        # sharded scans hit the same emulated SSD, not the page cache.
        shards = super().partition_rows(n_shards)
        for s in shards:
            s.seconds_per_byte = self.seconds_per_byte
        return shards


def _open(path, emulated: bool, spb: float) -> TileStore:
    if not emulated:
        return TileStore.open(path)
    st = EmulatedSSDStore(path, TileStore.open(path).header)
    st.seconds_per_byte = spb
    return st


def _pass_time(sem, x: np.ndarray) -> float:
    # warmup pass compiles; min-of-5 because the overlap-speedup gate is a
    # ratio of two of these — a median would let one scheduler hiccup on
    # either side flip the quick-mode floor
    return timeit(lambda: sem.multiply(x), repeat=5, stat=np.min)


def bench() -> List[Dict]:
    g = rmat(SCALE, 16, seed=5)        # full: 131k vertices, ~1.9M nnz
    assert g.nnz >= NNZ_MIN
    ct = to_chunked(g, T=T, C=C)
    path = os.path.join(tempfile.mkdtemp(prefix="bench_engine_"), "g")
    store = TileStore.write(path, ct, binary=True)
    # integer operand: bit-identity holds through the reordered store's
    # regrouped accumulation (integer fp adds are exact)
    x = np.random.default_rng(1).integers(
        -8, 9, (g.n_cols, P)).astype(np.float32)

    # The tentpole artifact: degree-reordered, delta-packed copy.
    path_opt = path + "_opt"
    store_opt = store.optimize(path_opt)

    # Calibrate the emulated SSD: one pass of stream time ~= one pass of
    # compute time (the paper's small-p balance; see module docstring).
    compute_t = _pass_time(SEMSpMM(TileStore.open(path),
                                   SEMConfig(chunk_batch=BATCH)), x)
    spb = compute_t / store.nbytes

    rows: List[Dict] = []
    results = {}
    for emulated in (False, True):
        tier = "emulated-ssd" if emulated else "page-cache"
        for name, cfg_kw, sharded in ENGINES:
            for opt in (False, True):
                ename = name + ("-opt" if opt else "")
                st = _open(path_opt if opt else path, emulated, spb)
                cfg = SEMConfig(chunk_batch=BATCH, **cfg_kw)
                if sharded:
                    engine = ShardedSEMSpMM(st, n_shards=sharded, config=cfg)
                else:
                    engine = SEMSpMM(st, cfg)
                t = _pass_time(engine, x)
                results[(tier, ename)] = dict(t=t, out=engine.multiply(x))
                # snapshot *after* the last pass: engine.passes counts
                # logical passes on both paths (a sharded multiply is one
                # pass), so h2d/pass is comparable across engines even
                # though a sharded pass issues more reads (one tail batch
                # per shard)
                stats = engine.io_stats if sharded else st.stats
                rows.append({
                    "p": P, "tier": tier, "engine": ename,
                    "t_pass_ms": t * 1e3,
                    "rows_per_s": store.header["n_rows"] / t,
                    "mb_streamed_per_pass": st.nbytes / 1e6,
                    "h2d_mb_per_pass": stats.h2d_bytes
                    / max(1, engine.passes) / 1e6,
                    "overlap_pct": 100.0 * stats.overlap_batches
                    / max(1, stats.reads),
                    "passes": (engine.passes if not sharded
                               else engine.passes * sharded),
                })
                if sharded:
                    engine.close()

    # -- asserted claims -----------------------------------------------------
    speedup = (results[("emulated-ssd", "serial")]["t"]
               / results[("emulated-ssd", "overlapped")]["t"])
    assert speedup >= MIN_SPEEDUP, \
        f"overlap speedup {speedup:.2f} < {MIN_SPEEDUP}"

    # binary store, device decode: the host path ships decoded int32 planes
    # plus synthesized float32 ones (12 B/lane); the device path ships the
    # raw uint16 planes (4 B/lane) and synthesizes both on device
    st_i32 = TileStore.open(path)
    sem_i32 = SEMSpMM(st_i32, SEMConfig(chunk_batch=BATCH,
                                        decode_on_device=False))
    sem_i32.multiply(x)
    st_u16 = TileStore.open(path)
    sem_u16 = SEMSpMM(st_u16, SEMConfig(chunk_batch=BATCH))
    sem_u16.multiply(x)
    lanes = -(-store.n_chunks // BATCH) * BATCH * C
    saved = st_i32.stats.h2d_bytes - st_u16.stats.h2d_bytes
    assert saved == 8 * lanes, (saved, 8 * lanes)

    # the compression claim, per tier and engine: >= 25% fewer bytes
    # streamed everywhere; >= 25% fewer h2d bytes wherever packed planes
    # ship (every engine but the host-decoded serial ablation)
    by_key = {(r["tier"], r["engine"]): r for r in rows}
    for tier in ("page-cache", "emulated-ssd"):
        for name, _, _ in ENGINES:
            raw_r, opt_r = by_key[(tier, name)], by_key[(tier, name + "-opt")]
            shrink = 1 - (opt_r["mb_streamed_per_pass"]
                          / raw_r["mb_streamed_per_pass"])
            assert shrink >= MIN_SHRINK, (tier, name, "streamed", shrink)
            if name != "serial":
                shrink = 1 - opt_r["h2d_mb_per_pass"] / raw_r["h2d_mb_per_pass"]
                assert shrink >= MIN_SHRINK, (tier, name, "h2d", shrink)

    # bit-identity: every engine, raw or optimized store, both tiers
    for tier in ("page-cache", "emulated-ssd"):
        a = results[(tier, "overlapped")]
        for name, _, _ in ENGINES:
            for suffix in ("", "-opt"):
                np.testing.assert_array_equal(
                    a["out"], results[(tier, name + suffix)]["out"])

    store_shrink = 1 - store_opt.nbytes / store.nbytes
    for r in rows:
        r["overlap_speedup_emulated"] = speedup
        r["h2d_index_saving_mb"] = saved / 1e6
        r["opt_store_shrink_pct"] = 100.0 * store_shrink
    return rows


def main() -> List[Dict]:
    return run_and_save("engine", bench)


if __name__ == "__main__":
    main()
