"""Fig 2: SCSR vs DCSC storage-size ratio, plus the optimized TileStore.

Two byte-exact, machine-independent tables:

* ``fig2_format_size`` — the paper's claim: SCSR/DCSC lands in the 45-70%
  band for real-world (power-law) graphs, and the binary-matrix bound
  0.4 <= ratio < 1 holds everywhere (paper §3.2).
* ``fig2_tilestore_compression`` — the on-disk win of
  ``TileStore.optimize`` on the streaming store itself, ablated per
  mechanism: delta packing alone (bit-identical results unconditionally),
  degree reordering alone (a locality prior, no packing), and both.  The
  combined mode must cut a binary power-law or clustered-SBM store by
  >= 25% — the floor the engine bench then re-verifies on streamed and
  h2d bytes (``bench_engine``) — and the persisted column permutation
  must stay small next to the store — it is O(V) int32 beside the
  store's O(E) planes, < 10% of the raw bytes at the paper's edge
  factors.

``REPRO_BENCH_QUICK=1`` shrinks the graphs to a seconds-long run; byte
ratios are scale-stable, so quick and full modes validate the same claims.
"""
from __future__ import annotations

import os
import tempfile
from typing import Dict, List

from repro.core.formats import CSR, from_coo_tiled, to_chunked
from repro.io.storage import TileStore
from repro.sparse.generate import erdos_renyi, rmat, sbm

from benchmarks.common import quick_mode, run_and_save

QUICK = quick_mode()
if QUICK:
    SCALE, T, C = 13, 2048, 512
else:
    SCALE, T, C = 16, 4096, 1024
MIN_SHRINK = 0.25   # the floor bench_engine holds on streamed/h2d bytes


def bench() -> List[Dict]:
    graphs = {
        "rmat-18-16": rmat(18, 16, seed=7),
        "rmat-16-8": rmat(16, 8, seed=3),
        "sbm-clustered": sbm(1 << 16, (1 << 16) * 16, 64, 16.0, seed=1),
        "erdos-renyi": erdos_renyi(1 << 16, (1 << 16) * 16, seed=2),
    }
    if QUICK:
        graphs = {
            "rmat-13-8": rmat(13, 8, seed=7),
            "sbm-clustered": sbm(1 << 13, (1 << 13) * 8, 16, 16.0, seed=1),
            "erdos-renyi": erdos_renyi(1 << 13, (1 << 13) * 8, seed=2),
        }
    rows = []
    for name, g in graphs.items():
        ts = from_coo_tiled(g, t=16384)
        scsr = ts.nbytes(0)
        dcsc = ts.dcsc_nbytes(0)
        csr = CSR.from_coo(g).nbytes(0)
        ratio = scsr / dcsc
        assert 0.4 <= ratio < 1.0, (name, ratio)
        rows.append({
            "graph": name, "n_vertices": g.n_rows, "n_edges": g.nnz,
            "scsr_mb": scsr / 1e6, "dcsc_mb": dcsc / 1e6,
            "csr_mb": csr / 1e6,
            "scsr_over_dcsc": ratio, "scsr_over_csr": scsr / csr,
        })
    return rows


def bench_tilestore() -> List[Dict]:
    graphs = {
        "powerlaw": rmat(SCALE, 16, seed=5),
        "sbm-clustered": sbm(1 << SCALE, (1 << SCALE) * 16, 64, 16.0,
                             seed=1),
    }
    tmp = tempfile.mkdtemp(prefix="bench_fmt_")
    rows: List[Dict] = []
    for name, g in graphs.items():
        path = os.path.join(tmp, name)
        store = TileStore.write(path, to_chunked(g, T=T, C=C), binary=True)
        for mode, reorder, pack in (("delta-only", False, True),
                                    ("reorder-only", True, False),
                                    ("both", True, True)):
            opt = store.optimize(f"{path}_{mode}", reorder=reorder,
                                 pack=pack)
            perm_path = f"{path}_{mode}.perm.npy"
            perm_b = os.path.getsize(perm_path) \
                if os.path.exists(perm_path) else 0
            shrink = 1.0 - opt.nbytes / store.nbytes
            n = opt.n_chunks
            packed = float((opt._tags[:n] != 0).sum()) / n
            rows.append({
                "graph": name, "n_edges": g.nnz, "mode": mode,
                "raw_mb": store.nbytes / 1e6, "opt_mb": opt.nbytes / 1e6,
                "perm_mb": perm_b / 1e6,
                "shrink_pct": 100.0 * shrink,
                "packed_frac": packed,
            })
            if mode == "both":
                assert shrink >= MIN_SHRINK, (name, shrink)
                assert perm_b < 0.10 * store.nbytes, (name, perm_b)
    return rows


def main() -> List[Dict]:
    rows = run_and_save("fig2_format_size", bench)
    rows += run_and_save("fig2_tilestore_compression", bench_tilestore)
    return rows


if __name__ == "__main__":
    main()
