"""Fig 2: SCSR vs DCSC storage-size ratio (byte-exact, machine-independent).

The paper reports 45-70% for real-world graphs.  We reproduce on scaled
R-MAT (power-law, "unclustered"), SBM (clustered), and Erdős-Rényi
(uniform), plus CSR for scale: SCSR/DCSC must land in the paper's band for
power-law graphs, and the binary-matrix bound 0.4 <= ratio < 1 must hold
everywhere (paper §3.2)."""
from __future__ import annotations

from typing import Dict, List

from repro.core.formats import CSR, from_coo_tiled
from repro.sparse.generate import erdos_renyi, rmat, sbm

from benchmarks.common import run_and_save


def bench() -> List[Dict]:
    graphs = {
        "rmat-18-16": rmat(18, 16, seed=7),
        "rmat-16-8": rmat(16, 8, seed=3),
        "sbm-clustered": sbm(1 << 16, (1 << 16) * 16, 64, 16.0, seed=1),
        "erdos-renyi": erdos_renyi(1 << 16, (1 << 16) * 16, seed=2),
    }
    rows = []
    for name, g in graphs.items():
        ts = from_coo_tiled(g, t=16384)
        scsr = ts.nbytes(0)
        dcsc = ts.dcsc_nbytes(0)
        csr = CSR.from_coo(g).nbytes(0)
        ratio = scsr / dcsc
        assert 0.4 <= ratio < 1.0, (name, ratio)
        rows.append({
            "graph": name, "n_vertices": g.n_rows, "n_edges": g.nnz,
            "scsr_mb": scsr / 1e6, "dcsc_mb": dcsc / 1e6,
            "csr_mb": csr / 1e6,
            "scsr_over_dcsc": ratio, "scsr_over_csr": scsr / csr,
        })
    return rows


def main() -> List[Dict]:
    return run_and_save("fig2_format_size", bench)


if __name__ == "__main__":
    main()
