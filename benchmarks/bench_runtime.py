"""Serving-runtime benchmark: I/O amortization of the shared-scan scheduler,
time-to-first-result of elastic mid-pass admission, and replica scan
scaling.

Serves N concurrent single-vector queries and a multi-tenant PageRank
workload three ways — naive per-request passes, shared-scan batching, and
shared-scan + hot-chunk cache — and reports bytes read from the slow tier
plus the amortization ratio (naive / shared).  Asserts the paper-derived
bound: a wave of N queries costs ceil(packed_cols / columns_that_fit)
streaming passes, not N.

The elastic section injects a one-shot query mid-pass (deterministically,
via the scheduler's boundary probe) into a running iterative wave on a
throttled "spindle" store and measures time-to-first-result with and
without mid-pass admission, on two clocks: chunk-batch boundaries
(deterministic — asserted) and wall seconds (reported; asserted with the
spindle throttle making passes slow enough for the saving to dominate
jitter).  The replica section streams a 2-way sharded wave from one
spindle vs from two replica copies — scan bandwidth scaling with spindles.
"""
from __future__ import annotations

import os
import shutil
import tempfile
import threading
import time

import numpy as np

from benchmarks.common import print_csv, save, timeit
from repro.apps.pagerank import (build_operator, dangling_vertices,
                                 pagerank_session)
from repro.core.formats import to_chunked
from repro.core.sem import SEMConfig, SEMSpMM
from repro.distributed.shard_scan import ShardedSEMSpMM
from repro.io.storage import TileStore
from repro.runtime import SharedScanScheduler
from repro.sparse.generate import rmat

N_REQ = 16


def _sem(path: str, budget: int = 1 << 30) -> SEMSpMM:
    return SEMSpMM(TileStore.open(path), SEMConfig(
        memory_budget_bytes=budget, chunk_batch=128))


class SpindleStore(TileStore):
    """TileStore throttled like one SSD spindle: reads sleep proportionally
    to bytes, serialized by a per-spindle lock — shard views of the same
    spindle contend for it, replica copies each get their own.  (The
    bench_engine EmulatedSSDStore models latency; this models *bandwidth
    ownership*, which is what replica routing buys.)"""

    seconds_per_byte = 0.0
    spindle_lock = None

    def read_batch_raw(self, start, count):
        delay = self.seconds_per_byte * self.header["record"] * count
        if self.spindle_lock is not None:
            with self.spindle_lock:
                time.sleep(delay)
        else:
            time.sleep(delay)
        return super().read_batch_raw(start, count)

    def partition_rows(self, n_shards):
        shards = super().partition_rows(n_shards)
        for s in shards:
            s.seconds_per_byte = self.seconds_per_byte
            s.spindle_lock = self.spindle_lock
        return shards


def _spindle(path: str, pass_seconds: float) -> SpindleStore:
    st = SpindleStore(path, TileStore.open(path).header)
    st.seconds_per_byte = pass_seconds / st.nbytes
    st.spindle_lock = threading.Lock()
    return st


def _ttfr(path: str, adj, elastic: bool, inject_at: int):
    """Run an iterative wave on a spindle store; a one-shot arrives at
    boundary ``inject_at``.  Returns (boundaries, seconds) to its result."""
    rng = np.random.default_rng(17)
    x = rng.standard_normal(adj.n_rows).astype(np.float32)
    box = {"req": None}

    def probe(sched, boundary):
        if box["req"] is None and sched.boundary_clock >= inject_at:
            box["req"] = sched.query(x, tenant_id="late-arrival")

    sem = SEMSpMM(_spindle(path, 0.25), SEMConfig(chunk_batch=128))
    sched = SharedScanScheduler(sem, use_cache=False, elastic=elastic,
                                boundary_probe=probe)
    sched.submit(pagerank_session(adj, max_iter=4, tenant_id="resident"))
    sched.run()
    req = box["req"]
    assert req is not None and req.done
    return (req.first_result_clock - req.submit_clock,
            req.t_first_result - req.t_submit)


def main() -> None:
    adj = rmat(13, 16, seed=3)
    p_op = build_operator(adj)
    ct = to_chunked(p_op, T=1024, C=256)
    path = os.path.join(tempfile.mkdtemp(prefix="bench_runtime_"), "g")
    TileStore.write(path, ct)
    n = p_op.n_cols
    rng = np.random.default_rng(0)
    xs = [rng.standard_normal(n).astype(np.float32) for _ in range(N_REQ)]
    rows = []

    # -- one-shot wave: naive vs shared vs shared+cache ----------------------
    sem = _sem(path)
    for x in xs:
        sem.multiply(x[:, None])
    naive = sem.store.stats.bytes_read
    rows.append(dict(workload="oneshot", mode="naive", passes=sem.passes,
                     bytes_read=naive, cache_hit_bytes=0, amortization=1.0))

    for use_cache, mode in ((False, "shared"), (True, "shared+cache")):
        sem = _sem(path)
        sched = SharedScanScheduler(sem, use_cache=use_cache)
        for i, x in enumerate(xs):
            sched.query(x, tenant_id=f"q{i}")
        sched.run()
        st = sem.store.stats
        p_fit = sem.columns_that_fit(N_REQ)
        bound = -(-N_REQ // p_fit)
        assert sched.total_scan_passes() <= bound, (sched.total_scan_passes(),
                                                    bound)
        rows.append(dict(workload="oneshot", mode=mode, passes=sem.passes,
                         bytes_read=st.bytes_read,
                         cache_hit_bytes=st.cache_hit_bytes,
                         amortization=naive / max(1, st.bytes_read)))

    # -- multi-tenant PageRank: per-tenant runs vs one shared scan -----------
    n_tenants, iters = 8, 15

    sem = _sem(path)
    dedicated = SharedScanScheduler(sem, use_cache=False)
    for i in range(n_tenants):  # sequential = naive: one tenant at a time
        dedicated.submit(pagerank_session(adj, max_iter=iters,
                                          tenant_id=f"pr{i}"))
        dedicated.run()
    naive_pr = sem.store.stats.bytes_read

    for use_cache, mode in ((False, "shared"), (True, "shared+cache")):
        sem = _sem(path)
        sched = SharedScanScheduler(sem, use_cache=use_cache)
        tenants = [sched.submit(pagerank_session(adj, max_iter=iters,
                                                 tenant_id=f"pr{i}"))
                   for i in range(n_tenants)]
        sched.run()
        assert all(t.done for t in tenants)
        st = sem.store.stats
        # N tenants iterating together: passes ~ iterations, not N * iters
        assert sem.passes <= iters + 1, sem.passes
        rows.append(dict(workload="pagerank_x8", mode=mode, passes=sem.passes,
                         bytes_read=st.bytes_read,
                         cache_hit_bytes=st.cache_hit_bytes,
                         amortization=naive_pr / max(1, st.bytes_read)))
    rows.insert(3, dict(workload="pagerank_x8", mode="naive",
                        passes=n_tenants * iters, bytes_read=naive_pr,
                        cache_hit_bytes=0, amortization=1.0))

    # -- time-to-first-result: mid-pass vs between-pass admission ------------
    n_batches = -(-TileStore.open(path).n_chunks // 128)
    inject_at = max(1, n_batches // 3)   # arrive a third into pass 1
    ttfr = {}
    for elastic, mode in ((False, "between-pass"), (True, "mid-pass")):
        boundaries, seconds = _ttfr(path, adj, elastic, inject_at)
        ttfr[mode] = (boundaries, seconds)
        rows.append(dict(workload="ttfr_late_arrival", mode=mode,
                         passes=-(-boundaries // n_batches),
                         bytes_read=0, cache_hit_bytes=0,
                         amortization=0.0,
                         boundaries_to_result=boundaries,
                         seconds_to_result=seconds))
    # the deterministic claim: elastic admission delivers strictly earlier
    # on the boundary clock, and (spindle-throttled) on the wall too
    assert ttfr["mid-pass"][0] < ttfr["between-pass"][0], ttfr
    assert ttfr["mid-pass"][1] < ttfr["between-pass"][1], ttfr

    # -- replica scaling: a sharded wave over 1 spindle vs 2 copies ----------
    replica_path = os.path.join(tempfile.mkdtemp(prefix="bench_replica_"),
                                "g")
    shutil.copy(path + ".bin", replica_path + ".bin")
    shutil.copy(path + ".json", replica_path + ".json")
    xw = rng.standard_normal((n, 8)).astype(np.float32)
    cfg = SEMConfig(chunk_batch=128)
    replica_t = {}
    for n_spindles, mode in ((1, "sharded-1-spindle"),
                             (2, "sharded-2-replicas")):
        src = _spindle(path, 0.25)
        reps = [_spindle(replica_path, 0.25)] if n_spindles == 2 else None
        with ShardedSEMSpMM(src, n_shards=2, config=cfg,
                            replicas=reps) as sh:
            t = timeit(lambda: sh.multiply(xw), repeat=2)
        replica_t[mode] = t
        rows.append(dict(workload="replica_scan", mode=mode,
                         passes=1, bytes_read=src.nbytes,
                         cache_hit_bytes=0, amortization=0.0,
                         boundaries_to_result=0, seconds_to_result=t))
    speedup = replica_t["sharded-1-spindle"] / replica_t["sharded-2-replicas"]
    print(f"# replica scan speedup (2 spindles / 1): {speedup:.2f}x")
    assert speedup > 1.2, replica_t

    save("runtime_serving", rows)
    print_csv("runtime_serving", rows)
    shared = [r for r in rows if r["mode"] == "shared"]
    assert all(r["amortization"] > 3.0 for r in shared), shared
    cached = [r for r in rows if r["mode"] == "shared+cache"]
    assert all(r["amortization"] >= s["amortization"]
               for r, s in zip(cached, shared))


if __name__ == "__main__":
    main()
