"""Serving-runtime benchmark: I/O amortization of the shared-scan scheduler,
time-to-first-result of elastic mid-pass admission, replica scan scaling,
and aggregate throughput of the concurrent-wave fleet.

Serves N concurrent single-vector queries and a multi-tenant PageRank
workload three ways — naive per-request passes, shared-scan batching, and
shared-scan + hot-chunk cache — and reports bytes read from the slow tier
plus the amortization ratio (naive / shared).  Asserts the paper-derived
bound: a wave of N queries costs ceil(packed_cols / columns_that_fit)
streaming passes, not N.

The elastic section injects a one-shot query mid-pass (deterministically,
via the scheduler's boundary probe) into a running iterative wave on a
throttled "spindle" store and measures time-to-first-result with and
without mid-pass admission, on two clocks: chunk-batch boundaries
(deterministic — asserted) and wall seconds (reported; asserted with the
spindle throttle making passes slow enough for the saving to dominate
jitter).  The replica section streams a 2-way sharded wave from one
spindle vs from two replica copies — scan bandwidth scaling with spindles.

The fleet section is the scale-OUT claim: one (unsharded) serving wave
streams from one spindle at a time, so on a 2-replica deployment a lone
scheduler leaves a spindle idle every pass.  A wave is provisioned at a
fixed capacity (one jit entry, one §3.6 wave's worth of column memory);
``ServingFleet`` runs N such waves concurrently over the shared
``ReplicaSet``, whose in-flight routing spreads simultaneous passes across
the copies.  Aggregate throughput (served columns / wall second) for a
query backlog of 4x one wave's capacity: fleet-of-2 must clear 1.3x the
single wide wave (it measures ~2x — both spindles busy), and fleet-of-4
shows the ceiling is the spindle count, not the wave count.

The churn section is the serve-under-mutation claim: ~1% of the edge set
arrives as delta-overlay inserts before every pass, and the median
per-pass cost vs a frozen baseline — both arms streaming from the
emulated-SSD spindle, the overlay riding in RAM — is the overlay's
serving overhead (gated <= 15% by ``check_regression.py``); churn then
stops, ``compact_ratio`` turns on, and serving continues until the
background rebuild installs and the log drains — compaction must
converge under load, at an unchanged version.

``REPRO_BENCH_QUICK=1`` (the CI regression gate, via ``benchmarks.run
--quick``) shrinks the graph and the spindle throttle to a seconds-long
run; ``benchmarks.run --json`` distills the trajectory numbers into
repo-root ``BENCH_runtime.json`` (see ``check_regression.py``).
"""
from __future__ import annotations

import os
import shutil
import tempfile
import threading
import time

import numpy as np

from benchmarks.common import print_csv, save, timeit
from repro.apps.pagerank import build_operator, pagerank_session
from repro.core.formats import to_chunked
from repro.core.sem import SEMConfig, SEMSpMM
from repro.distributed.shard_scan import ShardedSEMSpMM
from repro.io.storage import TileStore, UpdateBatch
from repro.runtime import ReplicaSet, ServingFleet, SharedScanScheduler
from repro.sparse.generate import rmat

QUICK = os.environ.get("REPRO_BENCH_QUICK") == "1"

# (rmat scale, chunk_batch, one-shot requests, PR tenants, PR iters,
#  spindle pass seconds, fleet wave capacity)
SCALE = 12 if QUICK else 13
CHUNK_BATCH = 32 if QUICK else 128
N_REQ = 8 if QUICK else 16
PR_TENANTS, PR_ITERS = (4, 8) if QUICK else (8, 15)
PASS_SECONDS = 0.08 if QUICK else 0.25
FLEET_CAPACITY = 4
CHURN_FRAC = 0.01                       # edges mutated per pass, as nnz frac
CHURN_PASSES = 8 if QUICK else 6


def _sem(path: str, budget: int = 1 << 30) -> SEMSpMM:
    return SEMSpMM(TileStore.open(path), SEMConfig(
        memory_budget_bytes=budget, chunk_batch=CHUNK_BATCH))


class SpindleStore(TileStore):
    """TileStore throttled like one SSD spindle: reads sleep proportionally
    to bytes, serialized by a per-spindle lock — shard views of the same
    spindle contend for it, replica copies each get their own.  (The
    bench_engine EmulatedSSDStore models latency; this models *bandwidth
    ownership*, which is what replica routing buys.)  The throttled window
    is bracketed by the in-flight gauge, so ``IOStats.max_reads_inflight``
    records how many concurrent waves actually queued on this spindle."""

    seconds_per_byte = 0.0
    spindle_lock = None

    def read_batch_raw(self, start, count):
        delay = self.seconds_per_byte * self.header["record"] * count
        self.stats.begin_read()
        try:
            if self.spindle_lock is not None:
                with self.spindle_lock:
                    time.sleep(delay)
            else:
                time.sleep(delay)
        finally:
            self.stats.end_read()
        return super().read_batch_raw(start, count)

    def partition_rows(self, n_shards):
        shards = super().partition_rows(n_shards)
        for s in shards:
            s.seconds_per_byte = self.seconds_per_byte
            s.spindle_lock = self.spindle_lock
        return shards


def _spindle(path: str, pass_seconds: float) -> SpindleStore:
    st = SpindleStore(path, TileStore.open(path).header)
    st.seconds_per_byte = pass_seconds / st.nbytes
    st.spindle_lock = threading.Lock()
    return st


def _ttfr(path: str, adj, elastic: bool, inject_at: int):
    """Run an iterative wave on a spindle store; a one-shot arrives at
    boundary ``inject_at``.  Returns (boundaries, seconds) to its result."""
    rng = np.random.default_rng(17)
    x = rng.standard_normal(adj.n_rows).astype(np.float32)
    box = {"req": None}

    def probe(sched, boundary):
        if box["req"] is None and sched.boundary_clock >= inject_at:
            box["req"] = sched.query(x, tenant_id="late-arrival")

    sem = SEMSpMM(_spindle(path, PASS_SECONDS), SEMConfig(
        chunk_batch=CHUNK_BATCH))
    with SharedScanScheduler(sem, use_cache=False, elastic=elastic,
                             boundary_probe=probe) as sched:
        sched.submit(pagerank_session(adj, max_iter=4, tenant_id="resident"))
        sched.run()
    req = box["req"]
    assert req is not None and req.done
    return (req.first_result_clock - req.submit_clock,
            req.t_first_result - req.t_submit)


def _fleet_section(path: str, replica_path: str, n: int, rows) -> dict:
    """Aggregate throughput: one wide wave vs a fleet of 2/4 concurrent
    waves, all on the same 2-spindle ReplicaSet, same per-wave capacity.
    Returns {mode: cols_per_s}."""
    cap = FLEET_CAPACITY
    n_req = 4 * cap * 2        # 4 passes' worth of backlog per 2 waves
    rng = np.random.default_rng(23)
    xs = [rng.standard_normal(n).astype(np.float32) for _ in range(n_req)]
    cfg = SEMConfig(chunk_batch=CHUNK_BATCH)

    # warm the (C, T, cap) jit entry so no config pays compile time
    with TileStore.open(path) as warm_store:
        SEMSpMM(warm_store, cfg).multiply(
            np.zeros((n, cap), np.float32))

    def spindle_rs() -> ReplicaSet:
        return ReplicaSet([_spindle(path, PASS_SECONDS),
                           _spindle(replica_path, PASS_SECONDS)], cfg)

    throughput = {}

    def record(mode, seconds, rs, waves):
        agg = rs.io_stats
        throughput[mode] = n_req / seconds
        rows.append(dict(
            workload="fleet_aggregate", mode=mode, passes=0,
            bytes_read=agg.bytes_read, cache_hit_bytes=0, amortization=0.0,
            boundaries_to_result=0, seconds_to_result=seconds,
            waves=waves, capacity=cap, cols_per_s=throughput[mode],
            max_spindle_queue=agg.max_reads_inflight,
            replica_scans=[s.scans for s in rs.router.states]))

    # one wide wave: a lone scheduler packs `cap` columns per pass but
    # streams one spindle at a time — the other replica idles
    rs = spindle_rs()
    with rs, SharedScanScheduler(rs, use_cache=False, elastic=True,
                                 capacity=cap) as sched:
        t0 = time.perf_counter()
        wide_reqs = [sched.query(x, tenant_id=f"w{i}")
                     for i, x in enumerate(xs)]
        sched.run()
        record("wide-1-wave", time.perf_counter() - t0, rs, 1)
        assert all(r.done for r in wide_reqs)

    for n_waves in (2, 4):
        rs = spindle_rs()
        with ServingFleet(rs, n_waves=n_waves, use_cache=False,
                          capacity=cap) as fleet:
            t0 = time.perf_counter()
            reqs = [fleet.query(x, tenant_id=f"f{i}")
                    for i, x in enumerate(xs)]
            fleet.drain(timeout=600)
            record(f"fleet-{n_waves}-waves", time.perf_counter() - t0, rs,
                   n_waves)
            assert all(r.done for r in reqs)
            if n_waves == 2:
                # both spindles actually served concurrent waves
                assert all(s.scans > 0 for s in rs.router.states)

    speedup2 = throughput["fleet-2-waves"] / throughput["wide-1-wave"]
    speedup4 = throughput["fleet-4-waves"] / throughput["wide-1-wave"]
    print(f"# fleet aggregate throughput: wide "
          f"{throughput['wide-1-wave']:.1f} cols/s, fleet-2 "
          f"{throughput['fleet-2-waves']:.1f} ({speedup2:.2f}x), fleet-4 "
          f"{throughput['fleet-4-waves']:.1f} ({speedup4:.2f}x)")
    # the acceptance bar: concurrent waves must beat the lone wave by >=1.3x
    # on 2 emulated spindles (measured ~2x: both spindles busy)
    assert speedup2 >= 1.3, throughput
    return throughput


def _churn_section(path: str, n: int, rows) -> None:
    """Serve under churn: ~CHURN_FRAC of E edge inserts land before every
    pass.  Both arms stream from the emulated SSD spindle (the paper's
    semi-external setting — the same throttle every other serving section
    measures against): the frozen arm serves the query stream on an
    untouched store; the churn-overlay arm additionally pays the
    delta-overlay work each pass, which rides in RAM and reads nothing
    from the spindle.  The median per-pass overhead is the trajectory
    number the CI gate holds at <= 15% (``check_regression.py``).  The
    churn-compact arm then stops churning, enables ``compact_ratio``, and
    keeps serving until the background rebuild installs and the log
    drains to empty — compaction must converge *while serving* (the
    rebuild contends for the same spindle), without changing the version
    the passes report."""
    cfg = SEMConfig(chunk_batch=CHUNK_BATCH)
    rng = np.random.default_rng(29)
    x = rng.standard_normal(n).astype(np.float32)

    def timed_passes(sched, sem, churn_nnz):
        """Median run_pass seconds over CHURN_PASSES one-shot queries,
        with ``churn_nnz`` edge inserts applied before each pass."""
        ts = []
        for i in range(CHURN_PASSES):
            if churn_nnz:
                sem.apply_updates(UpdateBatch.insert(
                    rng.integers(0, n, churn_nnz).astype(np.int64),
                    rng.integers(0, n, churn_nnz).astype(np.int64)))
            sched.query(x, tenant_id=f"c{i}")
            t0 = time.perf_counter()
            sched.run_pass()
            ts.append(time.perf_counter() - t0)
        return float(np.median(ts))

    with _spindle(path, PASS_SECONDS) as st:
        sem = SEMSpMM(st, cfg)
        with SharedScanScheduler(sem, use_cache=False) as sched:
            sched.query(x, tenant_id="warm")
            sched.run_pass()            # pay the jit entry outside the clock
            frozen_s = timed_passes(sched, sem, 0)

    with _spindle(path, PASS_SECONDS) as st:
        sem = SEMSpMM(st, cfg)
        base_nnz = st.nnz()
        churn_nnz = max(1, int(base_nnz * CHURN_FRAC))
        with SharedScanScheduler(sem, use_cache=False) as sched:
            # the warm pass carries a delta so the delta-path jit entries
            # are paid outside the clock, same as the base step's
            sem.apply_updates(UpdateBatch.insert(
                rng.integers(0, n, churn_nnz).astype(np.int64),
                rng.integers(0, n, churn_nnz).astype(np.int64)))
            sched.query(x, tenant_id="warm")
            sched.run_pass()
            overlay_s = timed_passes(sched, sem, churn_nnz)
            peak = max(r.delta_nnz for r in sched.reports)
            version = sem.version

            # convergence: churn stops, compaction turns on, serving keeps
            # going — install lands at a pass boundary, the log drains
            sched.compact_ratio = CHURN_FRAC / 2
            deadline = time.monotonic() + (120 if QUICK else 300)
            converged = False
            drain_passes = 0
            while time.monotonic() < deadline:
                sched.query(x, tenant_id=f"d{drain_passes}")
                sched.run_pass()
                drain_passes += 1
                h = st.handle
                if (st.generation >= 1 and h.delta_nnz == 0
                        and not h.compacting):
                    converged = True
                    break
                time.sleep(0.01)
            generation = st.generation
            assert sched.reports[-1].version == version, "version drifted"

    overhead = overlay_s / frozen_s - 1.0
    rows.append(dict(workload="serve_under_churn", mode="frozen",
                     passes=CHURN_PASSES, bytes_read=0, cache_hit_bytes=0,
                     amortization=0.0, seconds_per_pass=frozen_s))
    rows.append(dict(workload="serve_under_churn", mode="churn-overlay",
                     passes=CHURN_PASSES, bytes_read=0, cache_hit_bytes=0,
                     amortization=0.0, seconds_per_pass=overlay_s,
                     churn_frac=CHURN_FRAC, overhead_frac=overhead,
                     delta_nnz_peak=int(peak), version=version))
    rows.append(dict(workload="serve_under_churn", mode="churn-compact",
                     passes=drain_passes, bytes_read=0, cache_hit_bytes=0,
                     amortization=0.0,
                     compaction_converged=bool(converged),
                     generation=int(generation)))
    print(f"# serve-under-churn: frozen {frozen_s * 1e3:.1f} ms/pass, "
          f"{CHURN_FRAC:.0%} churn {overlay_s * 1e3:.1f} ms/pass "
          f"({overhead:+.1%}), delta peak {peak} nnz, compaction "
          f"{'converged' if converged else 'DID NOT CONVERGE'} at "
          f"generation {generation} in {drain_passes} serving passes")
    # the claim the gate holds across PRs: compaction converges under
    # serving; the <=15% overlay-overhead ceiling lives in the gate itself
    assert converged, "compaction did not install + drain while serving"


def main():
    adj = rmat(SCALE, 16, seed=3)
    p_op = build_operator(adj)
    ct = to_chunked(p_op, T=1024, C=256)
    path = os.path.join(tempfile.mkdtemp(prefix="bench_runtime_"), "g")
    TileStore.write(path, ct)
    n = p_op.n_cols
    rng = np.random.default_rng(0)
    xs = [rng.standard_normal(n).astype(np.float32) for _ in range(N_REQ)]
    rows = []

    # -- one-shot wave: naive vs shared vs shared+cache ----------------------
    sem = _sem(path)
    for x in xs:
        sem.multiply(x[:, None])
    naive = sem.store.stats.bytes_read
    rows.append(dict(workload="oneshot", mode="naive", passes=sem.passes,
                     bytes_read=naive, cache_hit_bytes=0, amortization=1.0))

    for use_cache, mode in ((False, "shared"), (True, "shared+cache")):
        sem = _sem(path)
        with SharedScanScheduler(sem, use_cache=use_cache) as sched:
            for i, x in enumerate(xs):
                sched.query(x, tenant_id=f"q{i}")
            sched.run()
            st = sem.store.stats
            p_fit = sem.columns_that_fit(N_REQ)
            bound = -(-N_REQ // p_fit)
            assert sched.total_scan_passes() <= bound, (
                sched.total_scan_passes(), bound)
        rows.append(dict(workload="oneshot", mode=mode, passes=sem.passes,
                         bytes_read=st.bytes_read,
                         cache_hit_bytes=st.cache_hit_bytes,
                         amortization=naive / max(1, st.bytes_read)))

    # -- multi-tenant PageRank: per-tenant runs vs one shared scan -----------
    sem = _sem(path)
    with SharedScanScheduler(sem, use_cache=False) as dedicated:
        for i in range(PR_TENANTS):  # sequential = naive: one at a time
            dedicated.submit(pagerank_session(adj, max_iter=PR_ITERS,
                                              tenant_id=f"pr{i}"))
            dedicated.run()
    naive_pr = sem.store.stats.bytes_read

    for use_cache, mode in ((False, "shared"), (True, "shared+cache")):
        sem = _sem(path)
        with SharedScanScheduler(sem, use_cache=use_cache) as sched:
            tenants = [sched.submit(pagerank_session(adj, max_iter=PR_ITERS,
                                                     tenant_id=f"pr{i}"))
                       for i in range(PR_TENANTS)]
            sched.run()
        assert all(t.done for t in tenants)
        st = sem.store.stats
        # N tenants iterating together: passes ~ iterations, not N * iters
        assert sem.passes <= PR_ITERS + 1, sem.passes
        rows.append(dict(workload=f"pagerank_x{PR_TENANTS}", mode=mode,
                         passes=sem.passes, bytes_read=st.bytes_read,
                         cache_hit_bytes=st.cache_hit_bytes,
                         amortization=naive_pr / max(1, st.bytes_read)))
    rows.insert(3, dict(workload=f"pagerank_x{PR_TENANTS}", mode="naive",
                        passes=PR_TENANTS * PR_ITERS, bytes_read=naive_pr,
                        cache_hit_bytes=0, amortization=1.0))

    # -- time-to-first-result: mid-pass vs between-pass admission ------------
    n_batches = -(-TileStore.open(path).n_chunks // CHUNK_BATCH)
    inject_at = max(1, n_batches // 3)   # arrive a third into pass 1
    def _measure_ttfr():
        return {mode: _ttfr(path, adj, elastic, inject_at)
                for elastic, mode in ((False, "between-pass"),
                                      (True, "mid-pass"))}

    ttfr = _measure_ttfr()
    if not ttfr["mid-pass"][1] < ttfr["between-pass"][1]:
        # wall clock on a loaded 2-core container can jitter past the
        # spindle throttle; the boundary clock below is the deterministic
        # claim and is asserted unconditionally — remeasure the wall once
        ttfr = _measure_ttfr()
    for mode in ("between-pass", "mid-pass"):
        boundaries, seconds = ttfr[mode]
        rows.append(dict(workload="ttfr_late_arrival", mode=mode,
                         passes=-(-boundaries // n_batches),
                         bytes_read=0, cache_hit_bytes=0,
                         amortization=0.0,
                         boundaries_to_result=boundaries,
                         seconds_to_result=seconds))
    # the deterministic claim: elastic admission delivers strictly earlier
    # on the boundary clock, and (spindle-throttled) on the wall too
    assert ttfr["mid-pass"][0] < ttfr["between-pass"][0], ttfr
    assert ttfr["mid-pass"][1] < ttfr["between-pass"][1], ttfr

    # -- replica scaling: a sharded wave over 1 spindle vs 2 copies ----------
    replica_path = os.path.join(tempfile.mkdtemp(prefix="bench_replica_"),
                                "g")
    shutil.copy(path + ".bin", replica_path + ".bin")
    shutil.copy(path + ".json", replica_path + ".json")
    xw = rng.standard_normal((n, 8)).astype(np.float32)
    cfg = SEMConfig(chunk_batch=CHUNK_BATCH)
    replica_t = {}
    for n_spindles, mode in ((1, "sharded-1-spindle"),
                             (2, "sharded-2-replicas")):
        src = _spindle(path, PASS_SECONDS)
        reps = ([_spindle(replica_path, PASS_SECONDS)]
                if n_spindles == 2 else None)
        with ShardedSEMSpMM(src, n_shards=2, config=cfg,
                            replicas=reps) as sh:
            t = timeit(lambda: sh.multiply(xw), repeat=2)
        replica_t[mode] = t
        rows.append(dict(workload="replica_scan", mode=mode,
                         passes=1, bytes_read=src.nbytes,
                         cache_hit_bytes=0, amortization=0.0,
                         boundaries_to_result=0, seconds_to_result=t))
    speedup = replica_t["sharded-1-spindle"] / replica_t["sharded-2-replicas"]
    print(f"# replica scan speedup (2 spindles / 1): {speedup:.2f}x")
    assert speedup > 1.2, replica_t

    # -- concurrent waves: fleet-of-N vs one wide wave -----------------------
    _fleet_section(path, replica_path, n, rows)

    # -- serving under edge churn: overlay overhead + compaction -------------
    _churn_section(path, n, rows)

    save("runtime_serving", rows)
    print_csv("runtime_serving", rows)
    shared = [r for r in rows if r["mode"] == "shared"]
    assert all(r["amortization"] > 3.0 for r in shared), shared
    cached = [r for r in rows if r["mode"] == "shared+cache"]
    assert all(r["amortization"] >= s["amortization"]
               for r, s in zip(cached, shared))
    return rows


if __name__ == "__main__":
    main()
