"""Serving-runtime benchmark: I/O amortization of the shared-scan scheduler.

Serves N concurrent single-vector queries and a multi-tenant PageRank
workload three ways — naive per-request passes, shared-scan batching, and
shared-scan + hot-chunk cache — and reports bytes read from the slow tier
plus the amortization ratio (naive / shared).  Asserts the paper-derived
bound: a wave of N queries costs ceil(packed_cols / columns_that_fit)
streaming passes, not N.
"""
from __future__ import annotations

import os
import tempfile

import numpy as np

from benchmarks.common import print_csv, save
from repro.apps.pagerank import (build_operator, dangling_vertices,
                                 pagerank_session)
from repro.core.formats import to_chunked
from repro.core.sem import SEMConfig, SEMSpMM
from repro.io.storage import TileStore
from repro.runtime import SharedScanScheduler
from repro.sparse.generate import rmat

N_REQ = 16


def _sem(path: str, budget: int = 1 << 30) -> SEMSpMM:
    return SEMSpMM(TileStore.open(path), SEMConfig(
        memory_budget_bytes=budget, chunk_batch=128))


def main() -> None:
    adj = rmat(13, 16, seed=3)
    p_op = build_operator(adj)
    ct = to_chunked(p_op, T=1024, C=256)
    path = os.path.join(tempfile.mkdtemp(prefix="bench_runtime_"), "g")
    TileStore.write(path, ct)
    n = p_op.n_cols
    rng = np.random.default_rng(0)
    xs = [rng.standard_normal(n).astype(np.float32) for _ in range(N_REQ)]
    rows = []

    # -- one-shot wave: naive vs shared vs shared+cache ----------------------
    sem = _sem(path)
    for x in xs:
        sem.multiply(x[:, None])
    naive = sem.store.stats.bytes_read
    rows.append(dict(workload="oneshot", mode="naive", passes=sem.passes,
                     bytes_read=naive, cache_hit_bytes=0, amortization=1.0))

    for use_cache, mode in ((False, "shared"), (True, "shared+cache")):
        sem = _sem(path)
        sched = SharedScanScheduler(sem, use_cache=use_cache)
        for i, x in enumerate(xs):
            sched.query(x, tenant_id=f"q{i}")
        sched.run()
        st = sem.store.stats
        p_fit = sem.columns_that_fit(N_REQ)
        bound = -(-N_REQ // p_fit)
        assert sched.total_scan_passes() <= bound, (sched.total_scan_passes(),
                                                    bound)
        rows.append(dict(workload="oneshot", mode=mode, passes=sem.passes,
                         bytes_read=st.bytes_read,
                         cache_hit_bytes=st.cache_hit_bytes,
                         amortization=naive / max(1, st.bytes_read)))

    # -- multi-tenant PageRank: per-tenant runs vs one shared scan -----------
    n_tenants, iters = 8, 15

    sem = _sem(path)
    dedicated = SharedScanScheduler(sem, use_cache=False)
    for i in range(n_tenants):  # sequential = naive: one tenant at a time
        dedicated.submit(pagerank_session(adj, max_iter=iters,
                                          tenant_id=f"pr{i}"))
        dedicated.run()
    naive_pr = sem.store.stats.bytes_read

    for use_cache, mode in ((False, "shared"), (True, "shared+cache")):
        sem = _sem(path)
        sched = SharedScanScheduler(sem, use_cache=use_cache)
        tenants = [sched.submit(pagerank_session(adj, max_iter=iters,
                                                 tenant_id=f"pr{i}"))
                   for i in range(n_tenants)]
        sched.run()
        assert all(t.done for t in tenants)
        st = sem.store.stats
        # N tenants iterating together: passes ~ iterations, not N * iters
        assert sem.passes <= iters + 1, sem.passes
        rows.append(dict(workload="pagerank_x8", mode=mode, passes=sem.passes,
                         bytes_read=st.bytes_read,
                         cache_hit_bytes=st.cache_hit_bytes,
                         amortization=naive_pr / max(1, st.bytes_read)))
    rows.insert(3, dict(workload="pagerank_x8", mode="naive",
                        passes=n_tenants * iters, bytes_read=naive_pr,
                        cache_hit_bytes=0, amortization=1.0))

    save("runtime_serving", rows)
    print_csv("runtime_serving", rows)
    shared = [r for r in rows if r["mode"] == "shared"]
    assert all(r["amortization"] > 3.0 for r in shared), shared
    cached = [r for r in rows if r["mode"] == "shared+cache"]
    assert all(r["amortization"] >= s["amortization"]
               for r, s in zip(cached, shared))


if __name__ == "__main__":
    main()
