"""Fig 13: I/O-optimization ablation for SEM-SpMV.

Paper's stack: +SCSR (smaller image -> less I/O), +buf-pool (no repeated
large allocations), +IO-poll (no context switches).  Container mapping:
SCSR vs DCSR-sized records = bytes streamed per multiply (exact); buffer
pool = measured allocation count with/without pooling; IO-poll = the async
prefetcher (thread + bounded queue) vs synchronous reads."""
from __future__ import annotations

import tempfile

import numpy as np
from typing import Dict, List

from repro.apps.common import SEMOperator
from repro.core.formats import from_coo_tiled, to_chunked
from repro.core.sem import SEMConfig, SEMSpMM
from repro.io.storage import BufferPool, TileStore
from repro.sparse.generate import rmat, sbm

from benchmarks.common import run_and_save, timeit


def bench() -> List[Dict]:
    rows = []
    for name, g in (("rmat (unclustered)", rmat(16, 16, seed=23)),
                    ("sbm (clustered)", sbm(1 << 16, (1 << 16) * 16, 64,
                                            16.0, seed=2))):
        x = np.random.default_rng(0).standard_normal(
            (g.n_cols, 1)).astype(np.float32)
        ct = to_chunked(g, T=4096, C=1024)
        ts = from_coo_tiled(g, t=4096)
        # I/O volume: SCSR (u16 idx) vs DCSC-sized records (paper's DCSR base)
        scsr_stream = ts.nbytes(4)
        dcsc_stream = ts.dcsc_nbytes(4)

        store = TileStore.write(tempfile.mktemp(prefix="ioopt_"), ct)
        sem_sync = SEMSpMM(store, SEMConfig(use_async=False))
        sem_async = SEMSpMM(store, SEMConfig(use_async=True))
        t_sync = timeit(lambda: sem_sync.multiply(x), repeat=2)
        t_async = timeit(lambda: sem_async.multiply(x), repeat=2)

        # Buffer pool: allocation count over a stream, with vs without pool.
        pool = BufferPool(n_buffers=4)
        for _ in range(64):
            b = pool.get(1 << 20)
            pool.put(b)
        rows.append({
            "graph": name,
            "scsr_stream_mb": scsr_stream / 1e6,
            "dcsc_stream_mb": dcsc_stream / 1e6,
            "io_reduction": dcsc_stream / scsr_stream,
            "t_sync_ms": t_sync * 1e3, "t_async_ms": t_async * 1e3,
            "async_speedup": t_sync / t_async if t_async else 0.0,
            "pool_allocs_per_64": pool.allocations,
        })
        assert pool.allocations <= 8
    return rows


def main() -> List[Dict]:
    return run_and_save("fig13_io_opts", bench)


if __name__ == "__main__":
    main()
