"""Semi-external SpGEMM bench: budget-vs-spill on a power-law A·A.

The workload the SpGEMM tentpole exists for: a power-law (R-MAT) graph
squared — multi-hop neighborhood expansion — whose product nnz is ~20x
the input nnz, with the partial-accumulator budget forced *below* the
product's footprint so the spill/merge machinery is on the measured path.

Three runs over the same store, all asserted bit-identical (binary input
⇒ exact arithmetic):

* **reference** — effectively unbounded budget: no spills; its peak
  partial bytes define how hard the next run is squeezed;
* **budgeted** — budget = peak/3 (never below 64 KiB): must spill at
  least once, must never hold more than the budget, must reproduce the
  reference product bit for bit — this is the timed run, and the row the
  CI gate (``check_regression.py`` ``compare_spgemm``) tracks;
* **optimized-A** — the same budgeted run over the column-relabeled,
  delta-compressed store: the encoding must not leak into the product.

The oracle is dense ``A @ A`` when the graph is small enough, and the
repo's own SpMM kernel otherwise: ``spmm_chunked(A, B[:, block])``
column blocks — SpGEMM checked against the paper's §3 kernel, not
against itself.

Quick mode (``REPRO_BENCH_QUICK=1``): scale-10 graph, seconds-long — the
CI gate's sizes.  Full mode: scale-12.
"""
from __future__ import annotations

import os
import shutil
import tempfile
import time
from typing import Dict, List

import numpy as np

from benchmarks.common import print_csv, quick_mode, save
from repro.core.formats import to_chunked
from repro.core.spgemm import materialize_dense, spgemm
from repro.core.spmm import spmm_chunked
from repro.io.storage import TileStore
from repro.sparse.generate import rmat

MIN_BUDGET = 1 << 16


def _oracle_identical(ct, graph, product_dense) -> bool:
    """Dense oracle on small graphs, spmm_chunked column blocks above."""
    n = graph.n_rows
    if n <= 2048:
        dense = graph.to_dense(np.float64)
        return np.array_equal(product_dense, (dense @ dense).astype(
            np.float32))
    bdense = graph.to_dense(np.float32)
    for lo in range(0, n, 1024):
        block = spmm_chunked(ct, bdense[:, lo:lo + 1024])
        if not np.array_equal(product_dense[:, lo:lo + 1024], block):
            return False
    return True


def bench() -> List[Dict]:
    quick = quick_mode()
    scale = 10 if quick else 12
    T, C = (256, 64) if quick else (512, 128)
    g = rmat(scale, 8, seed=31)
    ct = to_chunked(g, T=T, C=C)
    tmp = tempfile.mkdtemp(prefix="bench-spgemm-")
    rows: List[Dict] = []
    try:
        path = os.path.join(tmp, "a")
        TileStore.write(path, ct)
        a = TileStore.open(path)

        # reference: ample budget -> no spills, and the honest peak
        ref, ref_stats = spgemm(a, None, os.path.join(tmp, "ref"),
                                partial_budget_bytes=1 << 30)
        ref_dense = materialize_dense(ref)
        ref.close()
        assert ref_stats.spill_cycles == 0
        oracle_ok = _oracle_identical(ct, g, ref_dense)
        assert oracle_ok, "reference product disagrees with the oracle"

        # budgeted: squeezed to a third of the real footprint -> must spill,
        # must stay under budget, must not change a bit.  The timed run.
        budget = max(MIN_BUDGET, ref_stats.peak_partial_bytes // 3)
        t0 = time.perf_counter()
        prod, stats = spgemm(a, None, os.path.join(tmp, "p"),
                             partial_budget_bytes=budget)
        seconds = time.perf_counter() - t0
        bit_identical = np.array_equal(materialize_dense(prod), ref_dense)
        prod.close()
        assert stats.spill_cycles >= 1, "budget squeeze forced no spill"
        assert stats.peak_partial_bytes <= budget, \
            f"accumulator held {stats.peak_partial_bytes} > budget {budget}"
        assert bit_identical, "budgeted product is not bit-identical"

        # optimized-A: the encoding must not leak into the product
        ao = a.optimize(os.path.join(tmp, "a-opt"))
        prod_o, stats_o = spgemm(ao, None, os.path.join(tmp, "p-opt"),
                                 partial_budget_bytes=budget)
        opt_identical = np.array_equal(materialize_dense(prod_o), ref_dense)
        prod_o.close()
        ao.close()
        a.close()
        assert opt_identical, "optimized-A product is not bit-identical"
        assert stats_o.spill_cycles >= 1

        rows.append({
            "n": g.n_rows,
            "nnz_a": g.nnz,
            "product_nnz": stats.product_nnz,
            "expansion_ratio": stats.product_nnz / g.nnz,
            "partial_budget_bytes": int(budget),
            "ref_peak_partial_bytes": ref_stats.peak_partial_bytes,
            "peak_partial_bytes": stats.peak_partial_bytes,
            "spill_cycles": stats.spill_cycles,
            "merge_rounds": stats.merge_rounds,
            "spilled_mb": stats.spilled_bytes / 2**20,
            "seconds": seconds,
            "products_per_s": stats.expanded_products / seconds,
            "bit_identical": bool(bit_identical and oracle_ok
                                  and opt_identical),
            "quick": quick,
        })
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
    return rows


def main() -> List[Dict]:
    rows = bench()
    save("spgemm", rows)
    print_csv("spgemm", rows)
    return rows


if __name__ == "__main__":
    main()
