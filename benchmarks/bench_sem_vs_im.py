"""Fig 5: SEM-SpMM vs IM-SpMM for dense matrices of 1..8 columns, plus the
I/O volume per multiply (the container analogue of Fig 5b's throughput).

Paper claims: SEM reaches >= 65% of IM at p=1 and ~100% for p > 4.  On this
container the "SSD" is a memmap'd file with page cache, so absolute
SEM/IM gaps are smaller than the paper's; the *shape* (gap shrinks with p)
is the validated claim."""
from __future__ import annotations

import numpy as np
from typing import Dict, List

from repro.apps.common import IMOperator, SEMOperator
from repro.core.sem import SEMConfig
from repro.sparse.generate import rmat

from benchmarks.common import run_and_save, timeit


def bench() -> List[Dict]:
    g = rmat(17, 16, seed=11)          # 131k vertices, ~2M edges
    im = IMOperator.from_coo(g)
    sem = SEMOperator.from_coo(g, config=SEMConfig(chunk_batch=256))
    rng = np.random.default_rng(0)
    rows = []
    for p in (1, 2, 4, 8):
        x = rng.standard_normal((g.n_cols, p)).astype(np.float32)
        t_im = timeit(lambda: im.dot(x))
        before = sem.io_bytes_read
        t_sem = timeit(lambda: sem.dot(x))
        io_per_mult = (sem.io_bytes_read - before) / 4  # warmup+3 repeats
        rows.append({
            "p": p, "t_im_ms": t_im * 1e3, "t_sem_ms": t_sem * 1e3,
            "sem_over_im": t_im / t_sem if t_sem else 0.0,
            "io_mb_per_mult": io_per_mult / 1e6,
        })
    return rows


def main() -> List[Dict]:
    return run_and_save("fig5_sem_vs_im", bench)


if __name__ == "__main__":
    main()
