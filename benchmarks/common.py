"""Shared benchmark infrastructure.

Container-scale protocol (DESIGN.md §7): graphs are scaled to 1M-4M edges,
wall-times are indicative (1 CPU core), and the paper's *claims' shapes*
(ratios, crossovers, byte counts) are the validated quantities.  Byte-count
benchmarks (Fig 2 / Fig 8 / Table 2 volumes) are machine-independent and
exact.  Each bench writes results/bench/<name>.json and prints a CSV.
"""
from __future__ import annotations

import json
import os
import time
from typing import Callable, Dict, List

import numpy as np

RESULTS_DIR = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "results", "bench")


def quick_mode() -> bool:
    """True when the run is in CI quick mode (``benchmarks.run --quick``
    exports ``REPRO_BENCH_QUICK=1``): benches shrink to emulated-SSD sizes
    that finish in seconds and tag their output accordingly."""
    return os.environ.get("REPRO_BENCH_QUICK", "") not in ("", "0")


def timeit(fn: Callable, *, repeat: int = 3, warmup: int = 1,
           stat: Callable = np.median) -> float:
    """Wall seconds, ``stat`` over ``repeat`` runs (median by default;
    pass ``stat=np.min`` where a gated ratio of two measurements must not
    inherit scheduler noise from both sides)."""
    for _ in range(warmup):
        fn()
    times = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return float(stat(times))


def save(name: str, rows: List[Dict]) -> None:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, name + ".json"), "w") as f:
        json.dump(rows, f, indent=1)


def print_csv(name: str, rows: List[Dict]) -> None:
    if not rows:
        print(f"# {name}: no rows")
        return
    cols = list(rows[0].keys())
    print(f"# {name}")
    print(",".join(cols))
    for r in rows:
        print(",".join(f"{r[c]:.4g}" if isinstance(r[c], float) else str(r[c])
                       for c in cols))


def run_and_save(name: str, fn: Callable[[], List[Dict]]) -> List[Dict]:
    rows = fn()
    save(name, rows)
    print_csv(name, rows)
    return rows
