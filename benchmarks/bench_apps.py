"""Fig 14/15/16: application benchmarks — PageRank, eigensolver, NMF —
each run through both the IM and SEM operators.

Paper claims validated at container scale:
* PageRank (p=1): SEM ~ IM (one in-memory vector suffices); both converge
  to the dense reference.
* Eigensolver: SEM within ~2x of IM for small eigencounts; eigenvalues
  match dense numpy.
* NMF: per-iteration time improves as more columns fit in memory; the
  multiplicative updates monotonically reduce the Frobenius loss.
"""
from __future__ import annotations

import numpy as np
from typing import Dict, List

from repro.apps.common import IMOperator, SEMOperator
from repro.apps.eigensolver import lanczos_eigsh
from repro.apps.nmf import nmf, _frobenius_loss
from repro.apps.pagerank import (build_operator, dangling_vertices, pagerank,
                                 pagerank_dense_reference)

from repro.sparse.generate import rmat

from benchmarks.common import run_and_save, timeit


def bench() -> List[Dict]:
    rows = []
    g = rmat(12, 16, seed=31)                      # 4k vertices, ~65k edges
    # (dense oracles: eigvalsh is O(n^3) — 4k keeps it in seconds)
    # --- PageRank (Fig 14) --------------------------------------------------
    op_coo = build_operator(g)
    dang = dangling_vertices(g)
    im = IMOperator.from_coo(op_coo)
    sem = SEMOperator.from_coo(op_coo)
    ref = pagerank_dense_reference(g, max_iter=30)
    for name, op in (("IM", im), ("SEM", sem)):
        t = timeit(lambda: pagerank(op, dang, max_iter=30, tol=0.0), repeat=1)
        pr = pagerank(op, dang, max_iter=30, tol=0.0).scores
        err = float(np.abs(pr - ref).max())
        rows.append({"app": "pagerank30", "impl": name, "t_s": t,
                     "max_err_vs_dense": err, "metric": 0.0})
        assert err < 1e-5, (name, err)

    # --- Eigensolver (Fig 15) -----------------------------------------------
    und = g.dedup()
    sym = type(und)(und.n_rows, und.n_cols,
                    np.concatenate([und.rows, und.cols]),
                    np.concatenate([und.cols, und.rows]), None).dedup()
    im_s = IMOperator.from_coo(sym)
    sem_s = SEMOperator.from_coo(sym)
    dense = sym.to_dense(np.float64)
    ref = np.linalg.eigvalsh(dense)
    want = np.sort(ref[np.argsort(-np.abs(ref))][:4])  # largest |lambda|
    for name, op in (("IM", im_s), ("SEM", sem_s)):
        t = timeit(lambda: lanczos_eigsh(op, k=4), repeat=1)
        res = lanczos_eigsh(op, k=4)
        err = float(np.abs(np.sort(res.eigenvalues) - want).max())
        rows.append({"app": "eigs_k4", "impl": name, "t_s": t,
                     "max_err_vs_dense": err, "metric": float(want[-1])})
        assert err < 1e-4, (name, err)

    # --- NMF (Fig 16) ---------------------------------------------------------
    gd = rmat(12, 8, seed=37)
    im_a = IMOperator.from_coo(gd)
    im_at = IMOperator.from_coo(gd.transpose())
    sem_a = SEMOperator.from_coo(gd)
    sem_at = SEMOperator.from_coo(gd.transpose())
    a_sq = float(gd.nnz)  # binary matrix: ||A||_F^2 = nnz
    for name, (a, at) in (("IM", (im_a, im_at)), ("SEM", (sem_a, sem_at))):
        t = timeit(lambda: nmf(a, at, k=16, n_iter=5, seed=0,
                               track_loss=False), repeat=1)
        res = nmf(a, at, k=16, n_iter=8, seed=0, a_sq_sum=a_sq,
                  track_loss=True)
        assert res.losses[-1] <= res.losses[0], res.losses
        rows.append({"app": "nmf_k16_iter", "impl": name, "t_s": t / 5,
                     "max_err_vs_dense": 0.0,
                     "metric": float(res.losses[-1])})
    return rows


def main() -> List[Dict]:
    return run_and_save("fig14_16_apps", bench)


if __name__ == "__main__":
    main()
