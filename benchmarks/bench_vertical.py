"""Fig 10/11: SEM-SpMM with a 32-column dense matrix too big for "memory",
varying how many columns fit; plus the overhead breakdown.

Paper claims: >= 25% of IM with 1 column in memory, > 50% with > 4, ~80%
with all 32; the dominant overhead is lost data locality from vertical
partitioning (Vert-part), then sparse-matrix streaming (SpM-EM)."""
from __future__ import annotations

import tempfile

import numpy as np
from typing import Dict, List

from repro.apps.common import IMOperator, SEMOperator
from repro.core.sem import SEMConfig, SEMSpMM
from repro.io.storage import DenseStore, TileStore
from repro.core.formats import to_chunked
from repro.sparse.generate import rmat

from benchmarks.common import run_and_save, timeit


def bench() -> List[Dict]:
    g = rmat(16, 16, seed=17)          # 65k vertices, ~1M edges
    p = 32
    rng = np.random.default_rng(0)
    x = rng.standard_normal((g.n_cols, p)).astype(np.float32)

    im = IMOperator.from_coo(g)
    t_im = timeit(lambda: im.dot(x))

    ct = to_chunked(g, T=4096, C=1024)
    store = TileStore.write(tempfile.mktemp(prefix="vert_spm_"), ct)
    sem = SEMSpMM(store, SEMConfig())
    x_store = DenseStore(tempfile.mktemp(prefix="vert_x_"), g.n_cols, p)
    x_store.write_rows(0, x)
    rows = []
    for cols_fit in (1, 2, 4, 8, 16, 32):
        out_store = DenseStore(tempfile.mktemp(prefix="vert_o_"),
                               g.n_rows, p)
        t = timeit(lambda: sem.multiply_external(
            x_store, out_store, cols_in_memory=cols_fit), repeat=1)
        np.testing.assert_allclose(out_store.to_array(), im.dot(x),
                                   rtol=2e-3, atol=2e-3)
        rows.append({"cols_in_memory": cols_fit,
                     "t_sem_ms": t * 1e3, "t_im_ms": t_im * 1e3,
                     "frac_of_im": t_im / t if t else 0.0,
                     "passes": -(-p // cols_fit)})
    return rows


def main() -> List[Dict]:
    return run_and_save("fig10_vertical", bench)


if __name__ == "__main__":
    main()
