"""Fig 8: memory consumption of SEM-SpMM vs IM-SpMM vs CSR baselines.

Byte accounting is exact (machine-independent): SEM holds the dense
input/output columns plus bounded per-stream buffers; IM additionally holds
the whole sparse matrix; CSR-style implementations hold a bigger sparse
image (8-byte indices).  Paper claim: SEM ~ 1/10 of IM on big graphs."""
from __future__ import annotations

from typing import Dict, List

from repro.core.formats import CSR, from_coo_tiled, to_chunked
from repro.core.sem import SEMConfig
from repro.sparse.generate import rmat

from benchmarks.common import run_and_save


def bench(p: int = 1) -> List[Dict]:
    g = rmat(18, 16, seed=13)          # ~262k vertices, ~4M edges
    dense_bytes = 4 * g.n_rows * p * 2          # in + out dense matrices
    ct = to_chunked(g, T=4096, C=1024)
    ts = from_coo_tiled(g, t=4096)
    csr = CSR.from_coo(g)
    cfg = SEMConfig()
    stream_buffers = cfg.chunk_batch * (cfg.prefetch + 1) * (
        4 * 4 + 2 * ct.C * 2 + 4 * ct.C)        # meta + u16 idx + f32 vals
    rows = [
        {"impl": "SEM-SpMM", "sparse_mb": 0.0,
         "dense_mb": dense_bytes / 1e6,
         "buffers_mb": stream_buffers / 1e6,
         "total_mb": (dense_bytes + stream_buffers) / 1e6},
        {"impl": "IM-SpMM (chunked)", "sparse_mb": ct.nbytes() / 1e6,
         "dense_mb": dense_bytes / 1e6, "buffers_mb": 0.0,
         "total_mb": (ct.nbytes() + dense_bytes) / 1e6},
        {"impl": "IM-SCSR image", "sparse_mb": ts.nbytes(4) / 1e6,
         "dense_mb": dense_bytes / 1e6, "buffers_mb": 0.0,
         "total_mb": (ts.nbytes(4) + dense_bytes) / 1e6},
        {"impl": "CSR (MKL-like)", "sparse_mb": csr.nbytes(4) / 1e6,
         "dense_mb": dense_bytes / 1e6, "buffers_mb": 0.0,
         "total_mb": (csr.nbytes(4) + dense_bytes) / 1e6},
    ]
    for r in rows:
        r["p"] = p
    sem_total = rows[0]["total_mb"]
    im_total = rows[2]["total_mb"]
    # Paper's ~1/10 claim applies when the sparse matrix dominates (SpMV)
    # at billion-edge scale where the constant stream buffers amortize; at
    # container scale the buffers are a visible floor — assert the weaker
    # bound here, and note the buffer share in the row.
    if p == 1:
        assert sem_total < 0.4 * im_total, (sem_total, im_total)
    return rows


def bench_all() -> List[Dict]:
    return bench(1) + bench(8)


def main() -> List[Dict]:
    return run_and_save("fig8_memory", bench_all)


if __name__ == "__main__":
    main()
