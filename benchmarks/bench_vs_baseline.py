"""Fig 7/9: our tiled SpMM vs the CSR-style baseline (MKL/Tpetra stand-in).

MKL/Trilinos are unavailable offline; the baseline here is the same flat
scatter-add a CSR implementation performs (one unblocked pass, no cache
tiling, no load balancing) — the execution pattern the paper credits for
MKL/Tpetra's cache misses.  Paper claim: the tiled implementation wins,
and the gap grows with graph randomness."""
from __future__ import annotations

import numpy as np
from typing import Dict, List

import jax.numpy as jnp

from repro.apps.common import IMOperator
from repro.core.spmm import spmm_coo
from repro.sparse.generate import rmat, sbm

from benchmarks.common import run_and_save, timeit


def bench() -> List[Dict]:
    graphs = {
        "rmat-17-16": rmat(17, 16, seed=11),
        "sbm-clustered": sbm(1 << 17, (1 << 17) * 16, 64, 8.0, seed=4),
    }
    rng = np.random.default_rng(0)
    rows = []
    for name, g in graphs.items():
        im = IMOperator.from_coo(g)
        for p in (1, 8):
            x = rng.standard_normal((g.n_cols, p)).astype(np.float32)
            xj = jnp.asarray(x)
            t_tiled = timeit(lambda: im.dot(x))
            t_flat = timeit(
                lambda: np.asarray(spmm_coo(g, xj)))
            rows.append({
                "graph": name, "p": p,
                "t_tiled_ms": t_tiled * 1e3, "t_csr_flat_ms": t_flat * 1e3,
                "speedup": t_flat / t_tiled if t_tiled else 0.0,
            })
    return rows


def main() -> List[Dict]:
    return run_and_save("fig7_vs_baseline", bench)


if __name__ == "__main__":
    main()
