"""Fig 6: SEM-SpMV relative to IM-SpMV on stochastic-block-model graphs.

Paper claim: on *unclustered* (randomly-ordered) graphs the gap is small
(memory-bound compute hides I/O); on clustered graphs with more clusters /
higher in:out ratio the compute gets faster (cache-friendly) and the
relative I/O cost grows, widening the gap."""
from __future__ import annotations

import numpy as np
from typing import Dict, List

from repro.apps.common import IMOperator, SEMOperator
from repro.sparse.generate import sbm

from benchmarks.common import run_and_save, timeit


def bench() -> List[Dict]:
    n, e = 1 << 17, (1 << 17) * 16
    rng = np.random.default_rng(0)
    x = rng.standard_normal((n, 1)).astype(np.float32)
    rows = []
    for clusters, ratio, order in ((16, 4.0, "clustered"),
                                   (256, 4.0, "clustered"),
                                   (256, 16.0, "clustered"),
                                   (256, 16.0, "unclustered")):
        g = sbm(n, e, clusters, ratio, seed=5)
        if order == "unclustered":
            perm = np.random.default_rng(1).permutation(n)
            g = type(g)(g.n_rows, g.n_cols, perm[g.rows], perm[g.cols], g.vals)
        im = IMOperator.from_coo(g)
        sem = SEMOperator.from_coo(g)
        t_im = timeit(lambda: im.dot(x))
        t_sem = timeit(lambda: sem.dot(x))
        rows.append({
            "clusters": clusters, "in_out": ratio, "order": order,
            "t_im_ms": t_im * 1e3, "t_sem_ms": t_sem * 1e3,
            "sem_over_im": t_im / t_sem if t_sem else 0.0,
        })
    return rows


def main() -> List[Dict]:
    return run_and_save("fig6_sbm", bench)


if __name__ == "__main__":
    main()
