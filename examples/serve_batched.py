"""Example: batched serving (continuous batching) of an assigned arch.

  PYTHONPATH=src python examples/serve_batched.py [--arch mamba2-130m]

Prefills a wave of synthetic prompts into fixed batch slots, decodes them
together step by step (greedy), and reports token throughput — the serving
path whose full-scale layouts are proven by the decode_32k / long_500k
dry-run cells.
"""
import sys

from repro.launch.serve import main as serve_main

if __name__ == "__main__":
    argv = sys.argv[1:]
    if not any(a.startswith("--arch") for a in argv):
        argv = ["--arch", "mamba2-130m"] + argv
    sys.exit(serve_main(argv))
