"""End-to-end driver: semi-external-memory PageRank on a large graph.

The paper's headline application (Fig 14): the sparse matrix lives on the
slow tier and is streamed once per iteration; only the rank vector (p=1)
stays in memory.  At container scale this runs a multi-million-edge R-MAT
graph for 30 iterations and validates against the dense reference on a
subsample.

  PYTHONPATH=src python examples/pagerank_sem.py [--scale 18]
"""
import argparse
import time

import numpy as np

from repro.apps.common import SEMOperator
from repro.apps.pagerank import build_operator, dangling_vertices, pagerank
from repro.core.sem import SEMConfig
from repro.sparse.generate import rmat


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=18,
                    help="log2 #vertices (18 -> 262k vertices, ~4M edges)")
    ap.add_argument("--iters", type=int, default=30)
    args = ap.parse_args()

    print(f"== generating R-MAT scale={args.scale} ==")
    g = rmat(args.scale, 16, seed=0)
    print(f"{g.n_rows:,} vertices, {g.nnz:,} edges")

    print("== building SEM operator (sparse matrix -> slow tier) ==")
    op_coo = build_operator(g)
    sem = SEMOperator.from_coo(op_coo, config=SEMConfig(chunk_batch=512))
    dang = dangling_vertices(g)

    print(f"== {args.iters} PageRank iterations, streaming "
          f"{sem.sem.store.nbytes/1e6:.0f} MB/iter ==")
    t0 = time.perf_counter()
    res = pagerank(sem, dang, max_iter=args.iters, tol=0.0)
    dt = time.perf_counter() - t0
    print(f"done in {dt:.1f}s ({dt/args.iters*1e3:.0f} ms/iter); "
          f"residual={res.residuals[-1]:.2e}")
    print(f"I/O read: {sem.io_bytes_read/1e9:.2f} GB total "
          f"({sem.io_bytes_read/dt/1e6:.0f} MB/s sustained)")
    top = np.argsort(res.scores)[-5:][::-1]
    print("top-5 vertices:", list(zip(top.tolist(),
                                      np.round(res.scores[top], 6).tolist())))


if __name__ == "__main__":
    main()
