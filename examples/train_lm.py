"""Example: train an assigned-architecture LM end-to-end with the full
substrate — data pipeline, AdamW+WSD, checkpointing, straggler watch —
including a mid-run kill/restart to demonstrate fault tolerance.

  PYTHONPATH=src python examples/train_lm.py [--arch minicpm-2b] [--steps 200]

On this CPU container the reduced config trains a few hundred steps in
minutes; on real hardware the same Trainer drives the full config under
the dry-run-proven shardings.
"""
import argparse
import shutil
import tempfile

from repro.configs.base import ARCH_IDS, get_config
from repro.train.data import DataConfig
from repro.train.loop import TrainConfig, Trainer
from repro.train.optimizer import AdamWConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="minicpm-2b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    ckpt_dir = tempfile.mkdtemp(prefix="train_lm_ckpt_")
    mk = lambda: Trainer(
        cfg,
        TrainConfig(steps=args.steps, ckpt_every=50, ckpt_dir=ckpt_dir),
        AdamWConfig(lr=3e-3, schedule="wsd",
                    warmup_steps=args.steps // 10, total_steps=args.steps),
        DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                   global_batch=args.batch, seed=0))

    print(f"== training {args.arch} (reduced) for {args.steps} steps ==")
    t = mk()
    half = args.steps // 2
    t.run(half)
    mid_loss = t.metrics_log[-1]["loss"]
    print(f"step {t.step}: loss={mid_loss:.4f} — simulating a crash now")
    del t  # "node failure"

    t2 = mk()  # restores from the newest sealed checkpoint
    print(f"restarted at step {t2.step} "
          f"(data stream at batch {t2.data.next_index}) — resuming")
    last = t2.run(args.steps - t2.step)
    print(f"done: step {t2.step}, loss={last['loss']:.4f} "
          f"(grad_norm={last['grad_norm']:.3f}, lr={last['lr']:.2e})")
    shutil.rmtree(ckpt_dir, ignore_errors=True)


if __name__ == "__main__":
    main()
