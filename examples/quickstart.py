"""Quickstart: the paper's core object — semi-external-memory SpMM.

Builds a power-law graph, converts it to the SCSR+COO tiled format, runs
the same multiply three ways (flat-COO oracle, in-memory tiled, semi-
external streaming), validates they agree, and prints the format/IO stats
that make the paper's argument.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.apps.common import IMOperator, SEMOperator
from repro.core.formats import CSR, from_coo_tiled
from repro.core.spmm import spmm_coo
from repro.sparse.generate import rmat

import jax.numpy as jnp


def main():
    print("== build a scaled power-law graph (R-MAT) ==")
    g = rmat(16, 16, seed=0)  # 65k vertices, ~1M edges
    print(f"graph: {g.n_rows:,} vertices, {g.nnz:,} edges")

    print("\n== the paper's format: SCSR+COO tiles ==")
    ts = from_coo_tiled(g, t=16384)
    csr = CSR.from_coo(g)
    print(f"SCSR   : {ts.nbytes(0)/1e6:8.2f} MB  (2B row headers + 2B cols)")
    print(f"DCSC   : {ts.dcsc_nbytes(0)/1e6:8.2f} MB  "
          f"(SCSR/DCSC = {ts.nbytes(0)/ts.dcsc_nbytes(0):.2f}, "
          f"paper: 0.45-0.70 for real graphs)")
    print(f"CSR    : {csr.nbytes(0)/1e6:8.2f} MB  (the MKL/Tpetra baseline)")

    print("\n== one multiply, three execution tiers ==")
    rng = np.random.default_rng(0)
    x = rng.standard_normal((g.n_cols, 4)).astype(np.float32)
    oracle = np.asarray(spmm_coo(g, jnp.asarray(x)))

    im = IMOperator.from_coo(g)
    y_im = im.dot(x)
    np.testing.assert_allclose(y_im, oracle, rtol=2e-4, atol=2e-4)
    print("IM-SpMM  (tiled, in-memory)      : OK, matches oracle")

    sem = SEMOperator.from_coo(g)
    y_sem = sem.dot(x)
    np.testing.assert_allclose(y_sem, oracle, rtol=2e-4, atol=2e-4)
    print("SEM-SpMM (streamed from 'SSD')   : OK, matches oracle")
    print(f"  bytes streamed: {sem.io_bytes_read/1e6:.1f} MB "
          f"(the sparse matrix, read once per multiply)")
    print(f"  resident memory: dense columns only "
          f"({4*g.n_rows*4*2/1e6:.1f} MB) — the SEM contract")


if __name__ == "__main__":
    main()
