"""Example: multi-tenant graph-query serving over one on-"SSD" graph.

  PYTHONPATH=src python examples/serve_graph.py [--scale 12] [--tenants 6]

Usage note: the serving runtime turns the paper's Fig-5 crossover into a
scheduler.  Build the sparse operator once (``TileStore.write``), wrap it in
one ``SEMSpMM``, and hand that to ``SharedScanScheduler``.  Then submit any
mix of tenants — one-shot ``scheduler.query(x)`` multiplies, iterative
``PageRankSession`` / ``PowerIterationSession`` / ``LabelPropagationSession``
workloads — and call ``scheduler.run()``.  Every pass streams the sparse
matrix ONCE for the whole wave: N concurrent queries cost
``ceil(cols / columns_that_fit)`` passes, not N.  Leftover memory budget is
spent pinning hot chunk batches, so a draining workload converges toward
in-memory performance (watch ``cache_hit_bytes`` climb as tenants retire).

Tenants here all ride the PageRank operator P = A^T D^{-1}; point label
propagation at a store built from ``repro.apps.labelprop.build_operator``
when you need the symmetric-normalized adjacency instead.
"""
import argparse
import os
import tempfile

import numpy as np

from repro.apps.pagerank import build_operator, pagerank_session
from repro.core.formats import to_chunked
from repro.core.sem import SEMConfig, SEMSpMM
from repro.io.storage import TileStore
from repro.runtime import PowerIterationSession, SharedScanScheduler
from repro.sparse.generate import rmat


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=12)
    ap.add_argument("--tenants", type=int, default=6)
    args = ap.parse_args()

    adj = rmat(args.scale, 16, seed=1)
    print(f"graph: {adj.n_rows} vertices, {adj.nnz} edges")
    ct = to_chunked(build_operator(adj), T=1024, C=256)
    path = os.path.join(tempfile.mkdtemp(prefix="serve_graph_"), "g")
    store = TileStore.write(path, ct)
    print(f"operator on slow tier: {store.nbytes / 1e6:.1f} MB")

    sem = SEMSpMM(store, SEMConfig(memory_budget_bytes=256 << 20,
                                   chunk_batch=128))
    sched = SharedScanScheduler(sem)

    rng = np.random.default_rng(0)
    n = adj.n_rows
    tenants = [sched.submit(pagerank_session(
        adj, max_iter=10 + 3 * i, tenant_id=f"pagerank-{i}"))
        for i in range(args.tenants)]
    tenants.append(sched.submit(PowerIterationSession(
        rng.standard_normal(n).astype(np.float32), max_iter=25,
        tenant_id="spectral")))
    oneshots = [sched.query(rng.standard_normal(n).astype(np.float32),
                            tenant_id=f"query-{i}") for i in range(4)]

    read0 = store.stats.bytes_read
    for i, rep in enumerate(sched.run(), 1):
        print(f"pass {i:3d}: cols={rep.wave_cols:3d} "
              f"tenants={rep.tenants} retired={rep.retired} "
              f"read={rep.bytes_read / 1e6:7.2f}MB "
              f"cache_hit={rep.cache_hit_bytes / 1e6:7.2f}MB")

    total = store.stats.bytes_read - read0
    served = sum(t.iterations for t in tenants) + len(oneshots)
    naive = served * store.nbytes
    print(f"\nserved {len(tenants)} iterative tenants "
          f"({sum(t.iterations for t in tenants)} operator applications) "
          f"+ {len(oneshots)} one-shot queries")
    print(f"slow-tier reads: {total / 1e6:.1f} MB "
          f"(naive per-request serving: {naive / 1e6:.1f} MB, "
          f"amortization {naive / max(1, total):.1f}x)")
    if sched.cache is not None:
        print(f"hot-chunk cache: hit rate {sched.cache.stats.hit_rate:.0%}, "
              f"pinned {sched.cache.pinned_bytes / 1e6:.1f} MB")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
