"""Example: elastic multi-tenant graph-query serving over replicated
on-"SSD" copies of one graph — optionally as a concurrent-wave fleet.

  PYTHONPATH=src python examples/serve_graph.py [--scale 12] [--tenants 6]
                                                [--replicas 2] [--waves 1]

Usage note: the serving runtime turns the paper's Fig-5 crossover into a
scheduler.  Build the sparse operator once (``TileStore.write``), copy it
to one path per spindle/NUMA node, wrap the copies in a ``ReplicaSet``
(waves are routed to the healthiest, fastest copy; a failed copy is routed
around), and hand that to ``SharedScanScheduler(elastic=True)``.  Then
submit any mix of tenants — one-shot ``scheduler.query(x)`` multiplies,
iterative ``PageRankSession`` / ``PowerIterationSession`` /
``LabelPropagationSession`` workloads — and call ``scheduler.run()``.
Every pass streams the sparse matrix ONCE for the whole wave, and elastic
mode admits late arrivals at chunk-batch boundaries *inside* a running
pass: a request that shows up mid-pass starts accumulating tile rows
immediately and is delivered from a stitched partial pass roughly half a
pass earlier than between-pass admission — with bit-identical results.
Leftover memory budget still pins hot chunk batches.

With ``--waves N`` (N >= 2) the same tenants are served by a
``ServingFleet`` instead: N elastic schedulers run concurrently over the
shared ``ReplicaSet``, the front door routes each session to the wave with
the least estimated backlog (live columns x measured pass time), and the
global column/hot-chunk budget is arbitrated across waves.  On a
deployment with as many replica spindles as waves, aggregate throughput
scales with the wave count (see ``benchmarks/bench_runtime.py``).

With ``--hosts N`` (N >= 2) the demo goes cross-host: it spawns N local
``python -m repro.net.host`` processes — each one a full HostServer
wrapping its own ServingFleet over its own store copy — and serves the
tenant mix through a ``ClusterFrontDoor`` speaking the length-prefixed
wire protocol over localhost sockets.  The front door routes each tenant
to the least-estimated-backlog host (fed by heartbeat gauges), arbitrates
the global memory budget across hosts, and — because sessions are
deterministic replays — would resubmit a dead host's tenants to the
survivors bit-identically (see ``tests/test_net.py`` and
``benchmarks/bench_net.py`` for the kill-host drill).

Adding ``--partition`` (with ``--hosts >= 2``) additionally serves one
wide iterative query submitted with ``door.submit(spec,
partitioned=True)``: instead of routing the whole tenant to one host,
every pass spans *all* live hosts, each scanning only its nnz-balanced
contiguous tile-row slab of its own store copy, and the front door
stitches the returned row blocks in tile-row order — bit-identical to a
single-host serve, with the per-pass scan time divided across spindles.
The demo prints the slab -> host assignment the partition plan chose.

With ``--optimize-store`` the operator is re-encoded offline
(``TileStore.optimize``: degree-descending column reorder + uint8 delta
packing) before the replicas are copied out, and the demo reports the
slow-tier bytes actually saved, measured from ``IOStats``.  The serving
stack is oblivious: the permutation sidecar rides along with each replica
copy and the engine relabels operands at staging time.

The single-wave demo drips one-shot queries in mid-pass (via the
scheduler's boundary probe, so the run is deterministic) and prints each
pass's mid-pass admissions/completions plus every late query's
time-to-first-result in chunk-batch boundaries.
"""
import argparse
import os
import shutil
import subprocess
import sys
import tempfile
import time

import numpy as np

from repro.apps.pagerank import (build_operator, dangling_vertices,
                                 pagerank_session)
from repro.core.formats import to_chunked
from repro.core.sem import SEMConfig
from repro.io.storage import TileStore
from repro.net import ClusterFrontDoor
from repro.runtime import (PowerIterationSession, ReplicaSet, ServingFleet,
                           SessionSpec, SharedScanScheduler)
from repro.sparse.generate import rmat


def build_replicas(args):
    adj = rmat(args.scale, 16, seed=1)
    print(f"graph: {adj.n_rows} vertices, {adj.nnz} edges")
    ct = to_chunked(build_operator(adj), T=1024, C=256)
    root = tempfile.mkdtemp(prefix="serve_graph_")
    path = os.path.join(root, "replica0")
    store = TileStore.write(path, ct)
    raw_nbytes = store.nbytes
    exts = (".bin", ".json")
    if args.optimize_store:
        # offline re-encode (degree reorder + delta packing), then serve
        # the packed store: every replica copies the same optimized bytes
        # plus the persisted column permutation
        raw = os.path.join(root, "raw")
        for ext in exts:
            os.rename(path + ext, raw + ext)
        store = TileStore.open(raw).optimize(path)
        exts += (".perm.npy",)
        print(f"optimize(): {raw_nbytes / 1e6:.1f} MB raw -> "
              f"{store.nbytes / 1e6:.1f} MB reordered+packed "
              f"({1 - store.nbytes / raw_nbytes:.0%} smaller, perm sidecar "
              f"{os.path.getsize(path + '.perm.npy') / 1e6:.2f} MB)")
    paths = [path]
    for i in range(1, max(1, args.replicas)):
        p = os.path.join(root, f"replica{i}")
        for ext in exts:
            shutil.copy(path + ext, p + ext)
        paths.append(p)
    print(f"operator on slow tier: {store.nbytes / 1e6:.1f} MB "
          f"x {len(paths)} replica(s)")
    # small chunk batches -> many boundaries per pass: more mid-pass
    # admission points for the demo's late arrivals
    return adj, ReplicaSet(TileStore.open_replicas(paths),
                           SEMConfig(memory_budget_bytes=256 << 20,
                                     chunk_batch=32)), raw_nbytes


def print_stream_savings(replicas, total, raw_nbytes):
    """What the pass actually streamed (IOStats) vs what the raw store
    would have: every pass streams the whole store, so the ratio is exact."""
    if raw_nbytes <= replicas.store.nbytes:
        return
    raw_total = total * raw_nbytes / replicas.store.nbytes
    print(f"optimized store streamed {total / 1e6:.2f} MB where raw would "
          f"have streamed {raw_total / 1e6:.2f} MB "
          f"({1 - total / raw_total:.0%} fewer slow-tier bytes)")


def submit_tenants(target, adj, n_tenants, rng):
    tenants = [target.submit(pagerank_session(
        adj, max_iter=10 + 3 * i, tenant_id=f"pagerank-{i}"))
        for i in range(n_tenants)]
    tenants.append(target.submit(PowerIterationSession(
        rng.standard_normal(adj.n_rows).astype(np.float32), max_iter=25,
        tenant_id="spectral")))
    return tenants


def print_replica_states(replicas):
    for st in replicas.router.states:
        print(f"replica {st.replica_id}: {st.scans} scans, "
              f"{st.ewma_bps / 1e6:.0f} MB/s, "
              f"{'healthy' if st.healthy else 'DOWN'}")


def serve_single_wave(adj, replicas, args, raw_nbytes) -> int:
    """The elastic single-scheduler demo: late arrivals admitted mid-pass."""
    rng = np.random.default_rng(0)
    n = adj.n_rows
    late = {"queries": [], "xs": [rng.standard_normal(n).astype(np.float32)
                                  for _ in range(4)]}

    def drip(sched, boundary):
        i = len(late["queries"])
        if i < len(late["xs"]) and sched.boundary_clock >= 9 * (i + 1):
            late["queries"].append(
                sched.query(late["xs"][i], tenant_id=f"late-{i}"))

    read0 = replicas.io_stats.bytes_read
    with SharedScanScheduler(replicas, elastic=True, reserve_cols=2,
                             boundary_probe=drip) as sched:
        tenants = submit_tenants(sched, adj, args.tenants, rng)
        for i, rep in enumerate(sched.run(), 1):
            print(f"pass {i:3d}: cols={rep.wave_cols:3d}/{rep.capacity} "
                  f"tenants={rep.tenants} retired={rep.retired} "
                  f"mid-pass +{rep.admitted_midpass}/-{rep.completed_midpass} "
                  f"read={rep.bytes_read / 1e6:7.2f}MB "
                  f"cache_hit={rep.cache_hit_bytes / 1e6:7.2f}MB")

        n_batches = replicas.n_batches
        print("\nlate arrivals (admitted inside a running pass):")
        for q in late["queries"]:
            waited = q.first_result_clock - q.submit_clock
            print(f"  {q.tenant_id}: result after {waited} boundaries "
                  f"= {waited / n_batches:.2f} passes "
                  f"({(q.t_first_result - q.t_submit) * 1e3:.0f} ms)")

        total = replicas.io_stats.bytes_read - read0
        served = sum(t.iterations for t in tenants) + len(late["queries"])
        naive = served * replicas.store.nbytes
        print(f"\nserved {len(tenants)} iterative tenants "
              f"({sum(t.iterations for t in tenants)} operator applications) "
              f"+ {len(late['queries'])} mid-pass one-shot queries")
        print(f"slow-tier reads: {total / 1e6:.1f} MB "
              f"(naive per-request serving: {naive / 1e6:.1f} MB, "
              f"amortization {naive / max(1, total):.1f}x)")
        print_stream_savings(replicas, total, raw_nbytes)
        if sched.cache is not None:
            print(f"hot-chunk cache: hit rate "
                  f"{sched.cache.stats.hit_rate:.0%}")
        print_replica_states(replicas)
    return 0


def serve_fleet(adj, replicas, args, raw_nbytes) -> int:
    """Concurrent-wave serving: the same tenant mix dispatched across
    ``--waves`` elastic schedulers over the shared replica set."""
    rng = np.random.default_rng(0)
    n = adj.n_rows
    read0 = replicas.io_stats.bytes_read
    with ServingFleet(replicas, n_waves=args.waves) as fleet:
        t0 = time.perf_counter()
        tenants = submit_tenants(fleet, adj, args.tenants, rng)
        bursts = [fleet.query(rng.standard_normal(n).astype(np.float32),
                              tenant_id=f"burst-{i}") for i in range(8)]
        fleet.drain()
        wall = time.perf_counter() - t0

    sessions = tenants + bursts
    ops = sum(t.iterations for t in sessions)
    print(f"\nfleet of {args.waves} waves served {len(sessions)} tenants "
          f"({ops} operator applications) in {wall:.2f}s")
    for w in fleet.waves:
        mine = [s.tenant_id for s in sessions if s.wave_id == w.wave_id]
        print(f"  wave {w.wave_id}: {w.passes_served} passes, "
              f"ewma pass {w.ewma_pass_s * 1e3:.0f} ms, "
              f"{len(mine)} tenants: {', '.join(mine)}")
    total = fleet.io_stats.bytes_read - read0
    agg = fleet.io_stats
    print(f"slow-tier reads: {total / 1e6:.1f} MB; peak concurrent reads "
          f"on one replica: {agg.max_reads_inflight}")
    print_stream_savings(replicas, total, raw_nbytes)
    print_replica_states(replicas)
    return 0


def serve_cluster(args) -> int:
    """Cross-host serving: N spawned HostServer processes behind one
    ClusterFrontDoor speaking the wire protocol over localhost."""
    adj = rmat(args.scale, 16, seed=1)
    print(f"graph: {adj.n_rows} vertices, {adj.nnz} edges")
    ct = to_chunked(build_operator(adj), T=1024, C=256)
    root = tempfile.mkdtemp(prefix="serve_cluster_")
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in [os.path.join(repo, "src"),
                    env.get("PYTHONPATH", "")] if p)
    procs = []
    try:
        paths = [os.path.join(root, f"host{i}") for i in range(args.hosts)]
        store = TileStore.write(paths[0], ct)
        for p in paths[1:]:
            shutil.copy(paths[0] + ".bin", p + ".bin")
            shutil.copy(paths[0] + ".json", p + ".json")
        print(f"operator on slow tier: {store.nbytes / 1e6:.1f} MB "
              f"x {args.hosts} host(s), one store copy each")
        procs = [subprocess.Popen(
            [sys.executable, "-m", "repro.net.host", "--store", p,
             "--waves", str(max(1, args.waves))],
            stdout=subprocess.PIPE, env=env, text=True) for p in paths]
        ports = []
        for pr in procs:
            line = pr.stdout.readline()
            assert line.startswith("LISTENING "), line
            ports.append(int(line.split()[1]))
        print(f"hosts listening on ports {ports}")

        rng = np.random.default_rng(0)
        n = adj.n_rows
        with ClusterFrontDoor(memory_budget_bytes=512 << 20) as door:
            for port in ports:
                door.add_host("127.0.0.1", port)
            if args.partition:
                t0 = time.perf_counter()
                wide = door.submit(SessionSpec.power_iteration(
                    rng.standard_normal(n).astype(np.float32), tol=0.0,
                    max_iter=20, tenant_id="wide-spectral"),
                    partitioned=True)
                wide.wait(600)
                wall = time.perf_counter() - t0
                plan = wide.plan
                print(f"\npartitioned query '{wide.tenant_id}': "
                      f"{wide.iterations} passes in {wall:.2f}s, each pass "
                      f"spanning {plan.n_slabs} tile-row slab(s):")
                for slab in range(plan.n_slabs):
                    print(f"  slab {slab} -> {plan.assignment[slab].key}")
            t0 = time.perf_counter()
            tickets = [door.submit(SessionSpec.pagerank(
                n, dangling_vertices(adj).astype(np.uint8),
                max_iter=10 + 3 * i, tenant_id=f"pagerank-{i}"))
                for i in range(args.tenants)]
            tickets += [door.submit(SessionSpec.multiply(
                rng.standard_normal(n).astype(np.float32),
                tenant_id=f"burst-{i}")) for i in range(4)]
            tickets.append(door.submit(SessionSpec.bfs(
                np.array([0]), n, tenant_id="bfs-0")))
            door.drain(tickets, timeout=600)
            wall = time.perf_counter() - t0
            print(f"\ncluster of {args.hosts} hosts served {len(tickets)} "
                  f"tenants in {wall:.2f}s")
            for t in tickets:
                print(f"  {t.tenant_id}: host={t.host_key} "
                      f"iters={t.iterations} resubmits={t.resubmits}")
            agg = door.cluster_io_stats()
            print(f"cluster slow-tier reads: {agg.bytes_read / 1e6:.1f} MB")
            door.shutdown_hosts()
        return 0
    finally:
        for pr in procs:
            if pr.poll() is None:
                pr.terminate()
        for pr in procs:
            try:
                pr.wait(timeout=10)
            except subprocess.TimeoutExpired:
                pr.kill()
        shutil.rmtree(root, ignore_errors=True)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=12)
    ap.add_argument("--tenants", type=int, default=6)
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--waves", type=int, default=1,
                    help=">= 2 serves through a concurrent-wave "
                         "ServingFleet instead of one scheduler")
    ap.add_argument("--hosts", type=int, default=1,
                    help=">= 2 spawns that many local HostServer "
                         "processes and serves through the cross-host "
                         "ClusterFrontDoor instead")
    ap.add_argument("--partition", action="store_true",
                    help="with --hosts >= 2: also serve one wide iterative "
                         "query partitioned across every host (each host "
                         "scans only its nnz-balanced tile-row slab; the "
                         "front door stitches the row blocks per pass)")
    ap.add_argument("--optimize-store", action="store_true",
                    help="re-encode the store offline (degree-descending "
                         "column reorder + uint8 delta packing) and serve "
                         "the compressed replicas; prints the slow-tier "
                         "byte savings measured from IOStats")
    args = ap.parse_args()
    if args.hosts >= 2:
        return serve_cluster(args)
    adj, replicas, raw_nbytes = build_replicas(args)
    with replicas:
        if args.waves >= 2:
            return serve_fleet(adj, replicas, args, raw_nbytes)
        return serve_single_wave(adj, replicas, args, raw_nbytes)


if __name__ == "__main__":
    raise SystemExit(main())
